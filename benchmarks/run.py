"""Benchmark harness — one function per paper table/figure.

  table3  — dataset work statistics            (paper Table III)
  fig8    — SpGEMM speedups over scl-hash      (paper Figure 8)
  fig9    — spz execution-time breakdown       (paper Figure 9)
  fig10   — chunk-traffic: esc vs spz          (paper Figure 10 analogue)
  fig11   — dynamic mssort/mszip counts        (paper Figure 11)
  table4  — area table + TPU overhead model    (paper Table IV analogue)
  moe     — zipper MoE dispatch microbenchmark (framework integration)
  kernels — stream sort/merge kernel timings   (per-kernel perf)
  dispatch— engine-registry auto selection + batched execution path
  model   — learned-dispatch offline eval (LOBO regret vs oracle)

Prints ``name,us_per_call,derived`` CSV rows per the repo convention, and
writes one machine-readable ``BENCH_<section>.json`` per section run (the
CI benchmark-smoke artifact).
Run everything: PYTHONPATH=src python -m benchmarks.run
Subset:         PYTHONPATH=src python -m benchmarks.run fig8 fig11 --fast
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks import datasets
from repro.core import spgemm_engines as sg

# rows of the section currently running; flushed to BENCH_<section>.json
_ROWS: list[dict] = []


def _time_call(fn, repeat=1):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _emit(name, seconds, derived=""):
    print(f"{name},{seconds * 1e6:.1f},{derived}")
    _ROWS.append({"name": name, "us_per_call": round(seconds * 1e6, 1),
                  "derived": derived})


def _flush_json(section: str) -> None:
    path = f"BENCH_{section}.json"
    with open(path, "w") as f:
        json.dump({"section": section, "rows": _ROWS}, f, indent=1)
    _ROWS.clear()
    print(f"# wrote {path}")


# ---------------------------------------------------------------------------

def table3(mats, fast=False):
    print("# table3: name,us_per_call,nnz|density|avg_work|group_var")
    for name, A in mats:
        t, stats = _time_call(lambda: sg.work_stats(A, A))
        _emit(f"table3.{name}", t,
              f"nnz={stats['nnz']}|dens={stats['density']:.2e}|"
              f"work={stats['avg_work_per_row']:.1f}|"
              f"var={stats['work_var_per_group']:.2f}")
    if fast:
        return  # the spz driver comparison is minutes of host-driver time
    # host vs device-resident spz driver (the PR-3 before/after): same
    # engine semantics, so outputs must be BIT-identical between drivers
    # and structure-identical vs the scl-array oracle (values there differ
    # only by the oracle's float64 accumulation).
    print("# table3: spz host driver vs fused device-resident driver")
    # warm the host driver's chunk kernels once: their shapes are
    # matrix-independent by design (pow2 cap_s buckets), so this keeps
    # XLA compile time out of every matrix's host timing
    sg.spgemm_spz(mats[0][1], mats[0][1], R=16, backend="xla", driver="host")
    for name, A in mats:
        oracle = sg.spgemm_scl_array(A, A)
        t_host, (out_h, st_h) = _time_call(
            lambda: sg.spgemm_spz(A, A, R=16, backend="xla", driver="host"))
        sg.spgemm_spz(A, A, R=16, backend="xla", driver="fused")  # warm jits
        t_fused, (out_f, st_f) = _time_call(
            lambda: sg.spgemm_spz(A, A, R=16, backend="xla", driver="fused"),
            repeat=3)
        nnz = int(np.asarray(out_f.indptr)[-1])
        ident_host = (
            np.array_equal(np.asarray(out_h.indptr), np.asarray(out_f.indptr))
            and np.array_equal(np.asarray(out_h.indices)[:nnz],
                               np.asarray(out_f.indices)[:nnz])
            and np.array_equal(np.asarray(out_h.data)[:nnz],
                               np.asarray(out_f.data)[:nnz]))
        o_nnz = int(np.asarray(oracle.indptr)[-1])
        struct_oracle = (
            np.array_equal(np.asarray(oracle.indptr),
                           np.asarray(out_f.indptr))
            and np.array_equal(np.asarray(oracle.indices)[:o_nnz],
                               np.asarray(out_f.indices)[:nnz]))
        stats_match = (st_h.n_mszip == st_f.n_mszip
                       and st_h.zip_elems == st_f.zip_elems
                       and st_h.n_mssort == st_f.n_mssort)
        _emit(f"table3.spz-host.{name}", t_host,
              f"n_mszip={st_h.n_mszip}|zip_elems={st_h.zip_elems}")
        _emit(f"table3.spz-fused.{name}", t_fused,
              f"speedup_vs_host={t_host / t_fused:.2f}|"
              f"bit_identical_vs_host={ident_host}|"
              f"structure_identical_vs_scl_array={struct_oracle}|"
              f"stats_match={stats_match}")


def fig8(mats, fast=False):
    print("# fig8: impl.matrix,us_per_call,speedup_vs_scl_hash")
    rows = {}
    for name, A in mats:
        res = {}
        res["scl-hash"], _ = _time_call(lambda: sg.spgemm_scl_hash(A, A))
        res["scl-array"], _ = _time_call(lambda: sg.spgemm_scl_array(A, A))
        cap = int(sg.row_work(A, A).sum())
        _ = sg.spgemm_esc(A, A, cap)  # warm the jit cache
        res["vec-radix(esc)"], _ = _time_call(
            lambda: sg.spgemm_esc(A, A, cap), repeat=3)
        if not fast:
            res["spz"], _ = _time_call(
                lambda: sg.spgemm_spz(A, A, R=16, backend="xla",
                                      driver="host")[0])
            res["spz-rsort"], _ = _time_call(
                lambda: sg.spgemm_spz(A, A, R=16, rsort=True, backend="xla",
                                      driver="host")[0])
            sg.spgemm_spz(A, A, R=16, backend="xla", driver="fused")  # warm
            res["spz-fused"], _ = _time_call(
                lambda: sg.spgemm_spz(A, A, R=16, backend="xla",
                                      driver="fused")[0], repeat=3)
        base = res["scl-hash"]
        for impl, t in res.items():
            _emit(f"fig8.{impl}.{name}", t, f"speedup={base / t:.2f}")
        rows[name] = res
    # geomean speedups (the paper's headline numbers)
    for impl in next(iter(rows.values())).keys():
        sp = [rows[n]["scl-hash"] / rows[n][impl] for n in rows]
        gm = float(np.exp(np.mean(np.log(sp))))
        _emit(f"fig8.geomean.{impl}", 0.0, f"speedup={gm:.2f}")


def fig9(mats):
    print("# fig9: spz phase breakdown (fractions of total)")
    for name, A in mats:
        for label, rsort in (("spz", False), ("spz-rsort", True)):
            # host driver: the only one with a per-phase wall-clock split
            _, stats = sg.spgemm_spz(A, A, R=16, rsort=rsort, backend="xla",
                                     driver="host")
            tot = (stats.t_preprocess + stats.t_expand + stats.t_sort +
                   stats.t_output) or 1e-9
            _emit(f"fig9.{label}.{name}", tot,
                  f"pre={stats.t_preprocess / tot:.2f}|"
                  f"expand={stats.t_expand / tot:.2f}|"
                  f"sort={stats.t_sort / tot:.2f}|"
                  f"out={stats.t_output / tot:.2f}")


def fig10(mats):
    """Memory-traffic proxy: tuples moved per element (the paper measures
    L1D accesses). ESC (vec-radix): expansion (1 write) + 32-bit LSD radix
    sort = 4 passes x (read + scattered write) over the full product list
    + compression pass = ~10 tuple-movements per expanded tuple, with the
    scattered writes spanning cache lines (the effect Figure 10 shows).
    spz: every tuple is touched once per sort chunk + once per surviving
    merge round (duplicates drop out early), all unit-stride."""
    print("# fig10: traffic esc_elems vs spz chunk loads+stores")
    for name, A in mats:
        work = int(sg.row_work(A, A).sum())
        esc_elems = 10 * work
        _, st = sg.spgemm_spz(A, A, R=16, backend="xla", driver="host")
        spz_elems = st.sort_elems + st.zip_elems
        _emit(f"fig10.{name}", 0.0,
              f"esc_elems={esc_elems}|spz_elems={spz_elems}|"
              f"reduction={esc_elems / max(1, spz_elems):.2f}x")


def fig11(mats):
    # S=64 (4 lock-step groups of 16 batched per issue) keeps the python
    # driver tractable; instruction-count *ratios* match the S=16 ISA since
    # counts scale with ceil(rows/S) x per-group iterations either way.
    print("# fig11: dynamic mssortk+mszipk instruction counts")
    for name, A in mats:
        _, s0 = sg.spgemm_spz(A, A, R=16, S=64, backend="xla", driver="host")
        _, s1 = sg.spgemm_spz(A, A, R=16, S=64, rsort=True, backend="xla",
                              driver="host")
        _emit(f"fig11.{name}", 0.0,
              f"spz={s0.n_mssort + s0.n_mszip}|"
              f"rsort={s1.n_mssort + s1.n_mszip}|"
              f"reduction={(s0.n_mssort + s0.n_mszip) / max(1, s1.n_mssort + s1.n_mszip):.2f}x")


def table4():
    """Paper Table IV (12nm post-synthesis) transcription + the TPU-side
    cost model of the zipper primitives (see DESIGN.md §7)."""
    print("# table4: component,area_kum2,count_base|count_spz")
    rows = [
        ("baseline_PE", 0.45, "x256|-"),
        ("sparsezipper_PE", 0.51, "-|x256"),
        ("skew_buffer_16lane", 3.16, "x2|x2"),
        ("deskew_buffer_16lane", 3.16, "x1|x2"),
        ("matrix_register_16x512b", 0.96, "x16|x16"),
        ("popcount_logic", 0.45, "-|x1"),
    ]
    for n, a, c in rows:
        _emit(f"table4.{n}", 0.0, f"area={a}|{c}")
    base = 0.45 * 256 + 3.16 * 2 + 3.16 + 0.96 * 16
    spz = 0.51 * 256 + 3.16 * 2 + 3.16 * 2 + 0.96 * 16 + 0.45
    _emit("table4.total", 0.0,
          f"base={base:.1f}|spz={spz:.1f}|overhead={100 * (spz / base - 1):.2f}%")
    # TPU-side: zipper sort/merge cost per chunk relative to an MXU matmul
    R = 128
    sort_stages = sum(range(1, R.bit_length()))        # log^2 network
    merge_stages = (2 * R).bit_length() - 1
    _emit("table4.tpu_model", 0.0,
          f"R={R}|sort_stages={sort_stages}|merge_stages={merge_stages}|"
          "compress=1xMXU_128x128_matmul")


def moe_bench():
    print("# moe: zipper dispatch vs einsum dispatch (CPU wall time)")
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import base as cb
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(cb.get_smoke_config("arctic_480b"),
                              d_model=128, num_experts=16, top_k=2,
                              moe_d_ff=256, capacity_factor=1.5)
    key = jax.random.PRNGKey(0)
    p = moe_mod.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (8, 512, cfg.d_model), jnp.float32)
    for disp in ("einsum", "zipper"):
        fn = jax.jit(lambda p, x: moe_mod.moe_block(p, x, cfg,
                                                    dispatch=disp)[0])
        fn(p, x).block_until_ready()
        t, _ = _time_call(lambda: fn(p, x).block_until_ready(), repeat=5)
        _emit(f"moe.{disp}", t, f"tokens_per_s={8 * 512 / t:.0f}")


def kernels_bench():
    print("# kernels: stream sort/merge (pallas-interpret vs xla oracle)")
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    S, R = 256, 128
    keys = jnp.asarray(rng.integers(0, 64, (S, R)).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((S, R)).astype(np.float32))
    lens = jnp.asarray(rng.integers(0, R, S).astype(np.int32))
    for bk in ("xla", "pallas"):
        def fn():
            return ops.stream_sort(keys, vals, lens,
                                   backend=bk)[0].block_until_ready()
        fn()
        t, _ = _time_call(fn, repeat=3)
        _emit(f"kernels.stream_sort.{bk}", t,
              f"streams={S}|R={R}|Melem_per_s={S * R / t / 1e6:.1f}")


def dispatch_bench(mats, fast=False):
    """Engine-registry section: per-matrix auto selection (heuristic rule +
    chosen engine + the features that drove it), auto-dispatch wall time,
    and the batched single-compilation path vs lane-at-a-time execution."""
    from repro.core import dispatch as dp
    from repro.core.formats import batch_csr, random_sparse
    print("# dispatch: auto-selection + batched single-compilation path")
    # fresh private cache: measure selection, not a previous run's plans
    cache = dp.AutotuneCache(os.path.join(
        tempfile.mkdtemp(prefix="bench_autotune_"), "cache.json"))
    for name, A in mats:
        dp.clear_feature_cache()
        t_sel, info = _time_call(lambda: dp.explain(A, A))
        t_sel_hit, _ = _time_call(lambda: dp.explain(A, A), repeat=3)
        f = info["features"]
        # selection-only row: emitted in BOTH modes under its own name so
        # the CI --fast run and the committed full-mode baselines compare
        # like with like (a full auto multiply is too slow for the smoke
        # lane and gets its own dispatch.auto row below)
        _emit(f"dispatch.select.{name}", t_sel,
              f"engine={info['engine']}|rule={info['rule']}|"
              f"select_cached_us={t_sel_hit * 1e6:.1f}|"
              f"dens={f['density']:.2e}|var={f['work_var_per_group']:.2f}")
        if not fast:
            t, _ = _time_call(lambda: dp.spgemm(A, A, engine="auto",
                                                cache=cache), repeat=2)
            _emit(f"dispatch.auto.{name}", t,
                  f"engine={info['engine']}|rule={info['rule']}")
    # end-to-end engine rows on the first matrix (cached-plan serving path)
    A = mats[0][1]
    dp.spgemm(A, A, engine="esc")  # warm
    t, _ = _time_call(lambda: dp.spgemm(A, A, engine="esc"))
    _emit("dispatch.exec.esc", t, f"matrix={mats[0][0]}")
    # per-kernel-backend rows: the backend is a planned dimension, so the
    # same engine runs under each registered on-device backend (off-TPU
    # the pallas tier runs in interpret mode — labelled accordingly);
    # the xla timing doubles as the legacy dispatch.exec.spz-fused row
    import jax
    from repro.core import stream as kvstream
    from repro.core.formats import EMPTY
    # synthetic (S, L, R) work bucket for the stage-level kernel rows:
    # unsorted product streams for the fused pipeline, plus two sorted
    # unique EMPTY-padded partitions for the native merge kernel
    S, R, C = 8, 16, 4
    L = C * R
    rng = np.random.default_rng(7)
    b_keys = rng.integers(0, 4096, size=(S, L)).astype(np.int32)
    b_vals = rng.standard_normal((S, L)).astype(np.float32)
    b_lens = rng.integers(L // 2, L + 1, size=S).astype(np.int32)
    b_keys[np.arange(L)[None, :] >= b_lens[:, None]] = EMPTY

    def _sorted_side(seed):
        r = np.random.default_rng(seed)
        k = np.full((S, L), EMPTY, np.int32)
        v = np.zeros((S, L), np.float32)
        lens = r.integers(0, L + 1, size=S).astype(np.int32)
        for i, n in enumerate(lens):
            k[i, :n] = np.sort(r.choice(4096, size=n, replace=False))
            v[i, :n] = r.standard_normal(n)
        return k, v, lens

    mka, mva, mla = _sorted_side(1)
    mkb, mvb, mlb = _sorted_side(2)
    for bk in ("xla", "pallas"):
        label = bk if (bk != "pallas" or jax.default_backend() == "tpu") \
            else "pallas-interpret"
        reps = 1 if bk == "pallas" else 3
        dp.spgemm(A, A, engine="spz-fused", R=16, backend=bk)  # warm
        t_bk, _ = _time_call(
            lambda: dp.spgemm(A, A, engine="spz-fused", R=16, backend=bk),
            repeat=reps)
        if bk == "xla":
            _emit("dispatch.exec.spz-fused", t_bk, f"matrix={mats[0][0]}")
        _emit(f"dispatch.exec.spz-fused/{label}", t_bk,
              f"matrix={mats[0][0]}|backend={bk}")
        # stage rows: the device-resident merge primitive and the whole
        # sort+merge-tree bucket (pallas runs its single-kernel
        # fused_bucket; xla composes chunk_sort + the XLA merge tree),
        # jitted end-to-end the way the spz driver issues them
        merge_fn = jax.jit(
            lambda ka, va, la, kb_, vb, lb: kvstream.merge_partitions(
                ka, va, la, kb_, vb, lb, R=R, backend=bk)[0])
        fused_fn = jax.jit(
            lambda k, v, n: kvstream.fused_sort_merge(
                k, v, n, R=R, backend=bk)[0])

        def _merge():
            return merge_fn(mka, mva, mla, mkb, mvb,
                            mlb).block_until_ready()

        def _fused():
            return fused_fn(b_keys, b_vals, b_lens).block_until_ready()

        _merge()
        t_m, _ = _time_call(_merge, repeat=reps)
        _emit(f"dispatch.exec.spz-fused/{label}.merge", t_m,
              f"streams={S}|L={L}|R={R}|backend={bk}")
        _fused()
        t_f, _ = _time_call(_fused, repeat=reps)
        _emit(f"dispatch.exec.spz-fused/{label}.fused-bucket", t_f,
              f"streams={S}|L={L}|R={R}|C={C}|backend={bk}|"
              f"single_kernel={bk == 'pallas'}")
    # batched path: ragged request batch, one compilation across lanes
    lanes = [random_sparse(256, 256, d, seed=i)
             for i, d in enumerate((0.005, 0.01, 0.02, 0.04))]
    A = batch_csr(lanes, batch_cap=len(lanes))
    works = [int(sg.row_work(m, m).sum()) for m in lanes]
    cap = 1 << max(16, (max(works) - 1).bit_length())
    dp.spgemm_batched(A, A, engine="esc", cap_products=cap)  # warm the jit
    t_b, _ = _time_call(
        lambda: dp.spgemm_batched(A, A, engine="esc", cap_products=cap),
        repeat=2 if fast else 3)
    for m in lanes:
        sg.spgemm_esc(m, m, cap_products=cap)  # warm per-lane jit
    t_s, _ = _time_call(
        lambda: [sg.spgemm_esc(m, m, cap_products=cap) for m in lanes],
        repeat=2 if fast else 3)
    _emit("dispatch.batched.esc", t_b,
          f"lanes={len(lanes)}|sequential_us={t_s * 1e6:.1f}|"
          f"speedup={t_s / t_b:.2f}")
    if not fast:
        t_z, _ = _time_call(
            lambda: dp.spgemm_batched(A, A, engine="spz-host", R=16,
                                      backend="xla"))
        _emit("dispatch.batched.spz", t_z, f"lanes={len(lanes)}")
        dp.spgemm_batched(A, A, engine="spz-fused", R=16, backend="xla")  # warm
        t_zf, _ = _time_call(
            lambda: dp.spgemm_batched(A, A, engine="spz-fused", R=16,
                                      backend="xla"), repeat=3)
        _emit("dispatch.batched.spz-fused", t_zf,
              f"lanes={len(lanes)}|speedup_vs_host={t_z / t_zf:.2f}")


def model_bench(fast=False):
    """Learned-dispatch section: build a measurement dataset with autotune
    sweeps over a synthetic regime grid, replay the cached timings offline
    with leave-one-bucket-out splits (regret vs. oracle, selection accuracy
    vs. the heuristic table), and measure the model plan path against the
    cached-plan budget."""
    from repro.core import dispatch as dp
    from repro.core.formats import random_sparse
    from repro.models import dispatch_model as dm
    print("# model: learned dispatch — dataset, LOBO replay, plan budget")
    cache = dp.AutotuneCache(os.path.join(
        tempfile.mkdtemp(prefix="bench_model_"), "cache.json"))
    # dataset: one autotune sweep per (size, density) regime; every sweep
    # logs its full per-candidate timing vector + features into the cache
    sizes = (32, 48, 64, 96, 128, 192) if fast \
        else (32, 48, 64, 96, 128, 192, 256, 384)
    densities = (0.005, 0.02)
    t0 = time.perf_counter()
    n_sweeps = 0
    for i, n in enumerate(sizes):
        for j, dens in enumerate(densities):
            A = random_sparse(n, n, dens, seed=10 * i + j)
            B = random_sparse(n, n, dens, seed=500 + 10 * i + j)
            dp.plan(A, B, autotune=True, cache=cache, model=False)
            n_sweeps += 1
    t_ds = time.perf_counter() - t0
    samples = dm.samples_from_entries(cache.entries())
    _emit("model.dataset", t_ds,
          f"buckets={len(samples)}|sweeps={n_sweeps}")
    # leave-one-bucket-out replay: train on all-but-one bucket, select on
    # the held-out one, score against the bucket's own measured timings.
    # The heuristic comparator is scored generously: its engine pick is
    # charged the *best* measured time over that engine's backends.
    steps = 150 if fast else 300
    t0 = time.perf_counter()
    reg_m, reg_h, acc_m, acc_h = [], [], 0, 0
    for i, s in enumerate(samples):
        m = dm.DispatchModel.train(samples[:i] + samples[i + 1:],
                                   steps=steps)
        t = s["timings"]
        oracle = min(t, key=t.get)
        sel = m.select(s["features"], allowed=set(t))
        mc = sel.combo if sel is not None else oracle
        eng_h, _ = dp.choose_engine(s["features"], dp.DEFAULT_HEURISTICS)
        h_times = [v for c, v in t.items()
                   if dp.split_combo(c)[0] == eng_h]
        th = min(h_times) if h_times else max(t.values())
        reg_m.append(t[mc] / t[oracle] - 1.0)
        reg_h.append(th / t[oracle] - 1.0)
        acc_m += int(mc == oracle)
        acc_h += int(eng_h == dp.split_combo(oracle)[0])
    t_eval = time.perf_counter() - t0
    folds = max(1, len(samples))
    _emit("model.regret_vs_oracle", t_eval,
          f"regret_model={float(np.mean(reg_m)):.4f}|"
          f"regret_heuristic={float(np.mean(reg_h)):.4f}|"
          f"acc_model={acc_m / folds:.3f}|acc_heuristic={acc_h / folds:.3f}|"
          f"folds={len(samples)}")
    # final model on the full dataset, persisted next to the cache file —
    # exactly what an offline (re)train job produces
    t_tr, model = _time_call(lambda: dm.train_and_save(
        cache.entries(), dp.model_path_for(cache), steps=steps))
    _emit("model.train", t_tr,
          f"samples={model.n_samples}|candidates={len(model.candidates)}|"
          f"sigma={model.sigma:.3f}|version={model.version}")
    # plan-time budget: the model path (unseen bucket, floor pinned to 0
    # so every call takes the prediction instead of writing a heuristic
    # entry) vs the cached-plan path.  Same shape for both pairs — only
    # the nnz bucket differs — so the comparison isolates selection cost
    # from the shared per-plan work (operand validation, kwarg
    # resolution).
    A = random_sparse(80, 80, 0.03, seed=777)
    B = random_sparse(80, 80, 0.03, seed=778)
    conf = dp.explain(A, B, cache=cache)["model"]["confidence"]
    art = dm.DispatchModel.load(dp.model_path_for(cache))
    art.confidence_floor = 0.0
    p = dp.plan(A, B, cache=cache, model=art)
    t_model, _ = _time_call(lambda: dp.plan(A, B, cache=cache, model=art),
                            repeat=20)
    A0 = random_sparse(80, 80, 0.01, seed=888)
    B0 = random_sparse(80, 80, 0.01, seed=889)
    dp.plan(A0, B0, autotune=True, cache=cache, model=False)  # seed entry
    t_cached, _ = _time_call(
        lambda: dp.plan(A0, B0, cache=cache, model=False), repeat=20)
    _emit("model.select_us", t_model,
          f"cached_us={t_cached * 1e6:.1f}|"
          f"select_budget_ratio={t_model / t_cached:.2f}|"
          f"source={p.source}|confidence={conf:.3f}")


def serve_bench(fast=False):
    """Continuous-serving section: synthetic mixed SpGEMM traffic through
    the bucketed service (serving/spgemm_service.py) on the sharded
    plan/execute path.  Reports warmup vs steady-state request rate,
    latency percentiles, and the autotune-cache plan hit rate — the
    serving steady state the dispatch caches exist for.  The async phase
    (PR 9) measures the compile-ahead + async-flush pipeline: warm hit
    rate on the first post-warm flush wave, then open-loop paced tail
    latency with flushes on an executor, then coordinator pools under
    concurrent submitter threads."""
    from repro.core import dispatch as dp
    from repro.launch.serve_spgemm import make_traffic
    from repro.serving.spgemm_service import SpGemmService
    print("# serve: bucketed continuous service, warmup vs steady state")
    n = 96 if fast else 240
    cache = dp.AutotuneCache(os.path.join(
        tempfile.mkdtemp(prefix="bench_serve_"), "autotune.json"))
    dp.clear_feature_cache()
    service = SpGemmService(max_batch=8, flush_timeout=0.05, engine="auto",
                            cache=cache)
    traffic = make_traffic(n, seed=0)
    warmup = n // 4
    t0 = time.perf_counter()
    for A, B in traffic[:warmup]:
        service.submit(A, B)
        service.pump()
    service.drain()
    t_warm = time.perf_counter() - t0
    warm = service.stats()  # warmup-window stats, before the steady phase
    snap = (len(service.completed), len(service.flush_log))
    t1 = time.perf_counter()
    for A, B in traffic[warmup:]:
        service.submit(A, B)
        service.pump()
    service.drain()
    t_steady = time.perf_counter() - t1
    steady = service.stats(since_request=snap[0], since_flush=snap[1])
    _emit("serve.warmup", t_warm / max(1, warmup),
          f"reqs={warmup}|req_per_s={warmup / t_warm:.1f}|"
          f"hit_rate={warm['plan_hit_rate']:.2f}")
    _emit("serve.steady", t_steady / max(1, n - warmup),
          f"reqs={n - warmup}|req_per_s={(n - warmup) / t_steady:.1f}|"
          f"p50_us={steady['p50_latency_s'] * 1e6:.1f}|"
          f"p95_us={steady['p95_latency_s'] * 1e6:.1f}|"
          f"hit_rate={steady['plan_hit_rate']:.2f}|"
          f"flushes={steady['n_flushes']}|buckets={steady['n_buckets']}")

    # -- chaos phase: same traffic under a 10% kernel-fault rate plus a
    # one-shot worker kill; the availability row is the PR-6 resilience
    # gate (>= 0.99 expected: retries + worker re-bucketing + isolation)
    from repro.distributed.spgemm_shard import kill_worker_spec
    from repro.runtime import faultinject as fi
    n_chaos = 48 if fast else 120
    chaos_service = SpGemmService(
        max_batch=8, flush_timeout=0.05, engine="auto", cache=cache,
        policy=dp.RetryPolicy(max_attempts=3, backoff_base_s=0.0))
    t2 = time.perf_counter()
    with fi.injected(fi.FaultSpec(site="kernel.batched", kind="raise",
                                  rate=0.10),
                     kill_worker_spec(0), seed=7):
        for A, B in make_traffic(n_chaos, seed=1):
            chaos_service.submit(A, B)
            chaos_service.pump()
        chaos_service.drain()
    t_chaos = time.perf_counter() - t2
    cs = chaos_service.stats()
    _emit("serve.chaos.availability", t_chaos / max(1, n_chaos),
          f"reqs={n_chaos}|availability={cs.get('availability', 1.0):.4f}|"
          f"dead_letters={cs['n_dead_letters']}|degraded={cs['n_degraded']}|"
          f"retry_flush_rate={cs.get('flush_retry_rate', 0.0):.2f}")
    # p50_degraded_us only exists when degraded requests exist — a chaos
    # run lucky enough to serve everything planned must not report the
    # planned p50 as a fake "degraded" latency (compare_baselines skips
    # rows whose baseline us_per_call is 0, so the timing gate tolerates
    # either shape)
    degraded_p50 = cs.get("p50_latency_degraded_s", 0.0)
    degraded_info = f"n_degraded={cs['n_degraded']}|" \
                    f"p50_planned_us={cs.get('p50_latency_s', 0.0) * 1e6:.1f}"
    if cs["n_degraded"]:
        degraded_info += f"|p50_degraded_us={degraded_p50 * 1e6:.1f}"
    _emit("serve.chaos.degraded", degraded_p50, degraded_info)

    # -- async + compile-ahead phase (PR 9): pad buckets of the traffic
    # mix pre-compiled before the first request (PlanWarmer), flushes on
    # an executor so admission never blocks.  The warm row gates the
    # first post-warm flush wave (every bucket's first real flush should
    # land on a pre-compiled computation); the p50/p95 rows measure an
    # open-loop paced steady state — per-request latency is the real
    # wall clock from submit to completion, so these are the tail rows
    # the synchronous serve.steady p50 (~1.7 s with inline compiles)
    # is compared against.
    import threading

    from repro.core.formats import random_sparse
    from repro.launch.serve_spgemm import TRAFFIC_MIX
    from repro.serving.plan_warmer import PlanWarmer
    n_async = 48 if fast else 96
    a_cache = dp.AutotuneCache(os.path.join(
        tempfile.mkdtemp(prefix="bench_serve_async_"), "autotune.json"))
    reps = [(random_sparse(nn, nn, dd, seed=7 + i, pattern=pp),) * 2
            for i, (nn, dd, pp) in enumerate(TRAFFIC_MIX)]
    warmer = PlanWarmer(configured=reps)
    a_service = SpGemmService(max_batch=4, flush_timeout=0.02,
                              engine="auto", cache=a_cache,
                              async_flushes=2, warmer=warmer)
    t0 = time.perf_counter()
    a_service.prewarm()
    t_prewarm = time.perf_counter() - t0
    wave = 24 if fast else 36

    def _paced(n_reqs, seed, pace):
        for A, B in make_traffic(n_reqs, seed=seed):
            t_next = time.perf_counter() + pace
            a_service.submit(A, B)
            while time.perf_counter() < t_next:
                a_service.pump()
                time.sleep(0.002)
        a_service.drain()

    # first post-warm flush wave: the warm-hit gate — every bucket's
    # first real flush should land on a plan compiled ahead of traffic
    for A, B in make_traffic(wave, seed=11):
        a_service.submit(A, B)
        a_service.pump()
    a_service.drain()
    ws = a_service.stats()
    _emit("serve.warm.hit_rate", t_prewarm / max(1, ws["n_warmed"]),
          f"warmed={ws['n_warmed']}|prewarm_s={t_prewarm:.2f}|"
          f"warm_hit_rate={ws['warm_hit_rate']:.4f}|"
          f"first_wave_reqs={wave}|"
          f"availability={ws.get('availability', 1.0):.4f}")
    # absorption: the plan-level warm covers the jit_key, but the spz
    # lock-step driver compiles per (stream-bucket, chunk) shape under
    # it — a few more waves absorb those residuals before the measured
    # steady window (untimed, like every other bench's warmup; full
    # width even in fast mode, narrow waves leave combos unabsorbed)
    for seed in (13, 15):
        for A, B in make_traffic(36, seed=seed):
            a_service.submit(A, B)
            a_service.pump()
        a_service.drain()
    _paced(36, seed=17, pace=0.15)
    # steady tail latency: open-loop paced arrivals within the warmed
    # flush capacity — per-request latency is real submit-to-completion
    # wall clock, the number the synchronous serve.steady p50 pays
    # compiles inside
    snap = (len(a_service.completed), len(a_service.flush_log))
    pace = 0.12
    _paced(n_async, seed=12, pace=pace)
    a_service.close()
    st = a_service.stats(since_request=snap[0], since_flush=snap[1])
    _emit("serve.async.p50", st["p50_latency_s"],
          f"reqs={n_async}|pace_ms={pace * 1e3:.0f}|"
          f"req_per_s={st['req_per_s']:.1f}|"
          f"warm_hit_rate={st['warm_hit_rate']:.4f}|"
          f"availability={st.get('availability', 1.0):.4f}")
    _emit("serve.async.p95", st["p95_latency_s"],
          f"reqs={n_async}|pace_ms={pace * 1e3:.0f}|"
          f"p50_us={st['p50_latency_s'] * 1e6:.1f}")

    # -- multi-process phase: the same bucketed service dispatching its
    # flushes to a ProcessCoordinator worker pool (runtime/coordinator.py).
    # Throughput rows run one full untimed pass first so per-worker jax
    # import + kernel compile stay out of the timed window; the kill row
    # runs cold so the SIGKILL lands inside the measured traffic.  On a
    # single-core runner the w2/w4 rows measure dispatch overhead, not
    # parallel speedup — the availability fraction is the real gate.
    from repro.runtime.coordinator import ProcessCoordinator
    n_mp = 24 if fast else 48

    def _mp_traffic(pool, path, seed):
        mp = SpGemmService(
            max_batch=8, flush_timeout=0.05, engine="auto",
            cache=dp.AutotuneCache(path), coordinator=pool,
            policy=dp.RetryPolicy(max_attempts=3, backoff_base_s=0.0))
        t0 = time.perf_counter()
        for A, B in make_traffic(n_mp, seed=seed):
            mp.submit(A, B)
            mp.pump()
        mp.drain()
        return mp, time.perf_counter() - t0

    def _mp_pool_run(n_workers, specs=None):
        path = os.path.join(tempfile.mkdtemp(prefix="bench_mp_"),
                            "autotune.json")
        with ProcessCoordinator(n_workers, cache_path=path,
                                fault_specs=specs, fault_seed=5) as pool:
            if specs is None:
                # warm untimed on the SAME stream the timed passes run
                # (a different warm stream leaves spilled buckets
                # uncompiled on their spill worker, and that compile
                # then lands inside the timed window), then take the
                # best of two timed passes — on a shared single-core
                # runner one pass flaps enough to fake an inversion
                _mp_traffic(pool, path, seed=4)
                mp, wall = _mp_traffic(pool, path, seed=4)
                mp2, wall2 = _mp_traffic(pool, path, seed=4)
                if wall2 < wall:
                    mp, wall = mp2, wall2
            else:
                mp, wall = _mp_traffic(pool, path, seed=4)
            return mp, wall, pool.alive_count, \
                [e["event"] for e in pool.events]

    for w in (1, 2, 4):
        mp, wall, alive, _ = _mp_pool_run(w)
        ms = mp.stats()
        _emit(f"serve.multiproc.w{w}", wall / max(1, n_mp),
              f"workers={w}|reqs={n_mp}|req_per_s={n_mp / wall:.1f}|"
              f"availability={ms.get('availability', 1.0):.4f}|"
              f"dead_letters={ms['n_dead_letters']}|alive={alive}")

    # -- concurrent-submitter phase: the same pools driven by two client
    # threads submitting in parallel (the service admission path is
    # thread-safe); bucket-affinity dispatch keeps each pad bucket's
    # flushes on the worker that compiled it, so added workers must not
    # cost throughput (the old w4 < w2 inversion)
    def _mp_concurrent(pool, path, seed, n_sub=2):
        mp_svc = SpGemmService(
            max_batch=8, flush_timeout=0.05, engine="auto",
            cache=dp.AutotuneCache(path), coordinator=pool,
            policy=dp.RetryPolicy(max_attempts=3, backoff_base_s=0.0))
        streams = [make_traffic(n_mp // n_sub, seed=seed + k)
                   for k in range(n_sub)]

        def feed(stream):
            for A, B in stream:
                mp_svc.submit(A, B)
                mp_svc.pump()

        t0 = time.perf_counter()
        threads = [threading.Thread(target=feed, args=(s,))
                   for s in streams]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        mp_svc.drain()
        return mp_svc, time.perf_counter() - t0

    for w in (1, 2, 4):
        path = os.path.join(tempfile.mkdtemp(prefix="bench_mpc_"),
                            "autotune.json")
        with ProcessCoordinator(w, cache_path=path) as pool:
            # warm untimed on the same streams the timed passes run
            _mp_concurrent(pool, path, seed=21)
            mp, wall = _mp_concurrent(pool, path, seed=21)
            mp2, wall2 = _mp_concurrent(pool, path, seed=21)  # best of 2
            if wall2 < wall:
                mp, wall = mp2, wall2
            alive = pool.alive_count
        ms = mp.stats()
        _emit(f"serve.async.w{w}", wall / max(1, n_mp),
              f"workers={w}|submitters=2|reqs={n_mp}|"
              f"req_per_s={n_mp / wall:.1f}|"
              f"availability={ms.get('availability', 1.0):.4f}|"
              f"dead_letters={ms['n_dead_letters']}|alive={alive}")

    mp, wall, alive, events = _mp_pool_run(2, specs={
        0: [fi.FaultSpec(site="service.flush", kind="kill_process",
                         max_fires=1),
            fi.FaultSpec(site="kernel.batched", kind="raise", rate=0.10)],
        1: [fi.FaultSpec(site="kernel.batched", kind="raise", rate=0.10)],
    })
    ks = mp.stats()
    _emit("serve.multiproc.kill", wall / max(1, n_mp),
          f"workers=2|reqs={n_mp}|"
          f"availability={ks.get('availability', 1.0):.4f}|"
          f"dead_letters={ks['n_dead_letters']}|"
          f"worker_lost={events.count('worker_lost')}|"
          f"restarts={events.count('restart')}|alive_at_drain={alive}")


ALL = {"table3": table3, "fig8": fig8, "fig9": fig9, "fig10": fig10,
       "fig11": fig11, "table4": table4, "moe": moe_bench,
       "kernels": kernels_bench, "dispatch": dispatch_bench,
       "model": model_bench, "serve": serve_bench}

_NEEDS_MATS = ("table3", "fig8", "fig9", "fig10", "fig11", "dispatch")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("which", nargs="*", default=list(ALL), choices=list(ALL),
                    metavar="section")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow spz wall-time runs in fig8/dispatch")
    ap.add_argument("--limit", type=int, default=None,
                    help="first N matrices only")
    args = ap.parse_args()
    mats = None
    for name in args.which:
        fn = ALL[name]
        if name in _NEEDS_MATS:
            if mats is None:
                mats = [(n, datasets.build(n))
                        for n in datasets.names(args.limit)]
            if name in ("table3", "fig8", "dispatch"):
                fn(mats, fast=args.fast)
            else:
                fn(mats)
        elif name in ("serve", "model"):
            fn(fast=args.fast)
        else:
            fn()
        _flush_json(name)


if __name__ == "__main__":
    main()
