"""Insert the dry-run/roofline tables into EXPERIMENTS.md at the markers.

    PYTHONPATH=src python -m benchmarks.update_experiments
"""
import json
import re
import sys

from benchmarks.roofline_report import render, render_multipod_check


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    base = {k: v for k, v in results.items() if len(k.split("|")) == 3}
    md = open("EXPERIMENTS.md").read()
    dry = ("### Compile status (every assigned cell × both meshes)\n\n"
           + render_multipod_check(base))
    roof = ("### Single-pod (16×16 = 256 chips)\n\n" + render(base, "16x16")
            + "\n\n### Multi-pod (2×16×16 = 512 chips)\n\n"
            + render(base, "2x16x16"))
    md = re.sub(r"<!-- DRYRUN_TABLES -->.*?(?=\n## §Roofline)",
                "<!-- DRYRUN_TABLES -->\n\n" + dry + "\n",
                md, flags=re.S)
    md = re.sub(r"<!-- ROOFLINE_TABLES -->.*?(?=\n## §Perf)",
                "<!-- ROOFLINE_TABLES -->\n\n" + roof + "\n",
                md, flags=re.S)
    open("EXPERIMENTS.md", "w").write(md)
    print("EXPERIMENTS.md updated:", len(base), "cells")


if __name__ == "__main__":
    main()
