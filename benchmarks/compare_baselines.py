"""Compare freshly generated BENCH_<section>.json files against baselines.

The CI bench-smoke lane regenerates the benchmark JSON and runs this
script against the committed baselines; a row whose ``us_per_call`` grew
by more than ``--threshold``x (and is above the ``--min-us`` noise floor)
is a regression.  Rows are matched by name and only rows present on both
sides are compared — the committed baselines may carry extra full-mode
rows (e.g. the table3 spz driver comparison) that the ``--fast`` CI run
skips.

Default mode prints warnings and exits 0 (non-blocking); ``--strict``
exits 1 on any regression.  The CI lane starts non-blocking and is meant
to be flipped to ``--strict`` after one green run on the committed
baselines.

Usage:
    python -m benchmarks.compare_baselines --baseline <dir> --current <dir> \
        [--threshold 2.0] [--min-us 50] [--strict] [section ...]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_sections(path: str, sections: list[str] | None) -> dict[str, dict]:
    """Map section name -> {row name -> us_per_call} from BENCH_*.json."""
    out: dict[str, dict] = {}
    for fn in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        try:
            with open(fn) as f:
                data = json.load(f)
        except (OSError, ValueError) as e:
            print(f"warning: skipping unreadable {fn}: {e}")
            continue
        section = data.get("section") or \
            os.path.basename(fn)[len("BENCH_"):-len(".json")]
        if sections and section not in sections:
            continue
        out[section] = {r["name"]: float(r["us_per_call"])
                        for r in data.get("rows", [])
                        if "name" in r and "us_per_call" in r}
    return out


def load_derived(path: str, sections: list[str] | None,
                 key: str) -> dict[tuple, float]:
    """Map (section, row name) -> value for rows whose ``derived`` field
    carries a ``<key>=<number>`` entry (e.g. ``availability=0.99`` on
    the serve chaos rows, ``warm_hit_rate=1.0`` on the warm rows).
    These compare on the fraction, not the timing."""
    out: dict[tuple, float] = {}
    prefix = key + "="
    for fn in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
        try:
            with open(fn) as f:
                data = json.load(f)
        except (OSError, ValueError):
            continue
        section = data.get("section") or \
            os.path.basename(fn)[len("BENCH_"):-len(".json")]
        if sections and section not in sections:
            continue
        for r in data.get("rows", []):
            for part in str(r.get("derived", "")).split("|"):
                if part.startswith(prefix):
                    try:
                        out[(section, r["name"])] = float(
                            part.split("=", 1)[1])
                    except ValueError:
                        pass
    return out


def load_availability(path: str,
                      sections: list[str] | None) -> dict[tuple, float]:
    return load_derived(path, sections, "availability")


def compare_availability(base: dict[tuple, float], cur: dict[tuple, float],
                         *, floor: float) -> list[tuple]:
    """[(section, row, base_avail, cur_avail)] rows now under the floor.

    Availability is a success fraction, so the gate is an absolute floor
    rather than a ratio: a row that met the floor in the baseline and
    dropped below it in the current run is flagged."""
    drops = []
    for key in sorted(set(base) & set(cur)):
        if cur[key] < floor <= base[key]:
            drops.append((*key, base[key], cur[key]))
    return drops


def compare(base: dict[str, dict], cur: dict[str, dict], *,
            threshold: float, min_us: float) -> list[tuple]:
    """Return [(section, row, base_us, cur_us, ratio)] regressions."""
    regressions = []
    for section in sorted(set(base) & set(cur)):
        rows = set(base[section]) & set(cur[section])
        for name in sorted(rows):
            b, c = base[section][name], cur[section][name]
            # timings below the noise floor flap wildly in CI; rows whose
            # us_per_call is a placeholder (0.0 derived-only rows) too
            if b < min_us and c < min_us:
                continue
            if b <= 0.0:
                continue
            ratio = c / b
            if ratio > threshold:
                regressions.append((section, name, b, c, ratio))
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("sections", nargs="*", default=None,
                    help="restrict to these sections (default: all found)")
    ap.add_argument("--baseline", required=True,
                    help="directory with the committed BENCH_*.json")
    ap.add_argument("--current", default=".",
                    help="directory with the freshly generated BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when us_per_call grows by more than this "
                         "factor (default 2.0)")
    ap.add_argument("--tolerance", type=float, default=1.0,
                    help="extra multiplier on the threshold — the CI "
                         "escape hatch for known-noisy runners (e.g. "
                         "--tolerance 1.5 turns a 2.0x gate into 3.0x) "
                         "without rewriting the workflow gate")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="ignore rows below this many microseconds on both "
                         "sides (noise floor, default 50)")
    ap.add_argument("--availability-floor", type=float, default=0.99,
                    help="flag serve chaos rows whose availability "
                         "fraction falls below this floor (default 0.99; "
                         "always warn-only)")
    ap.add_argument("--warm-hit-floor", type=float, default=0.90,
                    help="flag serve rows whose warm_hit_rate falls "
                         "below this floor (default 0.90; always "
                         "warn-only) — the compile-ahead gate: the first "
                         "post-warm flush wave should land on "
                         "pre-compiled plans")
    ap.add_argument("--p50-floor-us", type=float, default=170000.0,
                    help="flag the serve.async.p50 row when its "
                         "us_per_call exceeds this ceiling (default "
                         "170000us ~= 10x better than the 1.67s "
                         "synchronous steady-state p50; always "
                         "warn-only)")
    ap.add_argument("--regret-ceiling", type=float, default=0.5,
                    help="flag model rows whose regret_model (mean "
                         "leave-one-bucket-out regret vs the measured "
                         "oracle) exceeds this ceiling, and any row "
                         "where the model's regret is not strictly "
                         "below the heuristic table's (default 0.5; "
                         "always warn-only)")
    ap.add_argument("--select-budget", type=float, default=2.0,
                    help="flag model rows whose select_budget_ratio "
                         "(model plan path vs cached-plan path) exceeds "
                         "this factor (default 2.0; always warn-only)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regressions (default: warn only)")
    args = ap.parse_args()
    base = load_sections(args.baseline, args.sections or None)
    cur = load_sections(args.current, args.sections or None)
    if not base:
        print(f"warning: no baseline BENCH_*.json under {args.baseline}; "
              "nothing to compare")
        return 0
    shared = set(base) & set(cur)
    compared = sum(len(set(base[s]) & set(cur[s])) for s in shared)
    threshold = args.threshold * args.tolerance
    regressions = compare(base, cur, threshold=threshold,
                          min_us=args.min_us)
    print(f"compared {compared} rows across {len(shared)} sections "
          f"(threshold {threshold:.1f}x, noise floor "
          f"{args.min_us:.0f}us)")
    for section, name, b, c, ratio in regressions:
        print(f"REGRESSION {section}: {name} {b:.1f}us -> {c:.1f}us "
              f"({ratio:.2f}x)")
    # availability rows (serve chaos) compare on the success fraction,
    # warn-only: flaky runner scheduling can cost a dead letter or two
    # without the resilience layer having regressed
    drops = compare_availability(
        load_availability(args.baseline, args.sections or None),
        load_availability(args.current, args.sections or None),
        floor=args.availability_floor)
    for section, name, b, c in drops:
        print(f"AVAILABILITY DROP {section}: {name} {b:.4f} -> {c:.4f} "
              f"(floor {args.availability_floor:.2f}, warn-only)")
    # warm-hit + async-p50 gates compare the *current* run against
    # absolute floors (warn-only): compile-ahead warming should keep the
    # first post-warm flush wave on pre-compiled plans, and the async
    # steady-state p50 an order of magnitude under the synchronous row
    for (section, name), v in sorted(
            load_derived(args.current, args.sections or None,
                         "warm_hit_rate").items()):
        if v < args.warm_hit_floor:
            print(f"WARM-HIT DROP {section}: {name} {v:.4f} < floor "
                  f"{args.warm_hit_floor:.2f} (warn-only)")
    for section in sorted(cur):
        p50 = cur[section].get("serve.async.p50")
        if p50 is not None and p50 > args.p50_floor_us:
            print(f"P50 CEILING {section}: serve.async.p50 {p50:.1f}us > "
                  f"{args.p50_floor_us:.0f}us (warn-only)")
    # learned-dispatch gates (warn-only, absolute — compared on the
    # current run): the LOBO replay's model regret must stay under the
    # ceiling AND strictly below the heuristic table's regret, and the
    # model plan path must stay within the cached-plan time budget
    cur_regret = load_derived(args.current, args.sections or None,
                              "regret_model")
    cur_h_regret = load_derived(args.current, args.sections or None,
                                "regret_heuristic")
    for key, v in sorted(cur_regret.items()):
        section, name = key
        if v > args.regret_ceiling:
            print(f"REGRET CEILING {section}: {name} regret_model "
                  f"{v:.4f} > {args.regret_ceiling:.2f} (warn-only)")
        h = cur_h_regret.get(key)
        if h is not None and v >= h:
            print(f"REGRET vs HEURISTIC {section}: {name} regret_model "
                  f"{v:.4f} >= regret_heuristic {h:.4f} — the model is "
                  "not beating the rules table (warn-only)")
    for (section, name), v in sorted(
            load_derived(args.current, args.sections or None,
                         "select_budget_ratio").items()):
        if v > args.select_budget:
            print(f"SELECT BUDGET {section}: {name} model plan path "
                  f"{v:.2f}x the cached-plan path > "
                  f"{args.select_budget:.1f}x budget (warn-only)")
    if not regressions:
        print("no regressions")
        return 0
    if args.strict:
        return 1
    print(f"{len(regressions)} regression(s) — warn-only mode "
          "(pass --strict to fail)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
