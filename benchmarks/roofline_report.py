"""Render dryrun_results.json as the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report [results.json]
"""
from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 2**30:.2f}"


def render(results: dict, mesh_filter: str = "16x16") -> str:
    lines = [
        "| arch | shape | peak GiB/dev | compute s | memory s | collective s"
        " | dominant | MODEL/HLO flops | roofline frac | mem frac |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        r = results[key]
        if r.get("mesh") != mesh_filter:
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR: "
                         f"{r['error'][:60]} | | | | | | | |")
            continue
        rl = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{fmt_bytes(r['memory']['peak_bytes_per_device'])} | "
            f"{rl['compute_s']:.4f} | {rl['memory_s']:.4f} | "
            f"{rl['collective_s']:.4f} | {rl['dominant'].replace('_s','')} | "
            f"{rl['useful_flop_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | {rl['memory_fraction']:.3f} |")
    return "\n".join(lines)


def render_multipod_check(results: dict) -> str:
    lines = ["| arch | shape | 16x16 | 2x16x16 |", "|---|---|---|---|"]
    seen = {}
    for key, r in results.items():
        seen.setdefault((r["arch"], r["shape"]), {})[r["mesh"]] = \
            "ERROR" if "error" in r else "ok"
    for (a, s), m in sorted(seen.items()):
        lines.append(f"| {a} | {s} | {m.get('16x16', '-')} | "
                     f"{m.get('2x16x16', '-')} |")
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print("## Single-pod (16x16 = 256 chips) roofline\n")
    print(render(results, "16x16"))
    print("\n## Multi-pod (2x16x16 = 512 chips) roofline\n")
    print(render(results, "2x16x16"))
    print("\n## Compile status matrix\n")
    print(render_multipod_check(results))


if __name__ == "__main__":
    main()
