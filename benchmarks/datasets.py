"""Synthetic stand-ins for the paper's SuiteSparse matrices (Table III).

SuiteSparse is not available offline, so each evaluated matrix is replaced
by a generator matched on the structural features that drive SparseZipper's
behaviour: density, average per-row work, and per-16-row work variance
(Table III columns). Names keep the paper's labels with a ``syn-`` prefix.
Sizes are scaled (~2-6K rows) so the full benchmark suite runs in minutes
on one CPU core; the structural ratios, not absolute sizes, are what the
algorithms respond to.
"""
from __future__ import annotations

import numpy as np

from repro.core.formats import CSR, csr_from_coo, random_sparse

# (paper name, pattern, n_rows, density, skew) — ordered like Table III
# (descending per-16-row work variance).
SPECS = [
    ("p2p",      "powerlaw", 1024, 3.0e-3, 2.2),   # tiny work, high var
    ("wiki",     "powerlaw",  768, 1.6e-2, 1.7),   # heavy rows, high var
    ("soc",      "powerlaw", 1024, 1.0e-2, 1.8),
    ("ca-cm",    "powerlaw", 1024, 7.0e-3, 1.5),
    ("ndwww",    "powerlaw", 1536, 2.5e-3, 1.6),
    ("patents",  "uniform",  1536, 1.5e-3, 0.0),
    ("email",    "powerlaw", 1024, 6.0e-3, 1.3),
    ("scircuit", "banded",   1024, 4.0e-3, 0.0),
    ("bcsstk17", "blocked",   768, 2.5e-2, 0.0),   # dup-heavy compression
    ("usroads",  "banded",   1536, 1.5e-3, 0.0),   # work < chunk width
    ("p3d",      "banded",    768, 2.5e-2, 0.0),
    ("cage11",   "uniform",  1024, 4.0e-3, 0.0),
    ("m133-b3",  "uniform",  1536, 2.6e-3, 0.0),   # exactly-regular rows
]


def build(name: str) -> CSR:
    for n, pattern, rows, dens, skew in SPECS:
        if n == name:
            if n == "m133-b3":
                # the paper's m133-b3 has exactly 4 nnz/row, zero variance
                rng = np.random.default_rng(7)
                r = np.repeat(np.arange(rows), 4)
                c = rng.integers(0, rows, rows * 4)
                v = rng.standard_normal(rows * 4).astype(np.float32)
                return csr_from_coo(r, c, v, (rows, rows))
            return random_sparse(rows, rows, dens, seed=abs(hash(n)) % 2**31,
                                 pattern=pattern, skew=skew or 1.5)
    raise KeyError(name)


def names(limit=None):
    ns = [s[0] for s in SPECS]
    return ns[:limit] if limit else ns
