"""Lane-sharded batched SpGEMM: balanced lane->device assignment + shard_map.

SpArch's observation is that merge-tree throughput multiplies across
independent partitions, and the RISC-V SpGEMM study shows that *load
balance*, not raw FLOPs, decides vectorized SpGEMM throughput.  A
``BatchedCSR`` request batch is embarrassingly parallel across lanes, so
this module scales ``spgemm_batched`` by (1) assigning lanes to devices
with an LPT (longest-processing-time-first) greedy pass over per-lane
work — one heavy matrix must not serialize a device — and (2) running
each device's lane group in parallel:

  * **esc** (the jittable engine): one ``shard_map`` over a 1-D
    ``("lanes",)`` mesh (``launch/mesh.py::make_lane_mesh``, the same
    idiom as ``models/moe.py``), every device vmapping the ESC core
    over its local lane shard under one compilation;
  * **spz family** (host-orchestrated pipelines): the same balanced
    assignment executed group-at-a-time through the batched drivers —
    per-stream payloads are independent of which streams share a kernel
    issue (see ``core/spgemm.py``), so splitting the batch cannot change
    results.

Both paths produce output ``BatchedCSR``s bit-identical to the
single-device ``spgemm_batched``: planning is shared (same
``ExecutionPlan``, same static capacities), only the placement differs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core.formats import EMPTY, BatchedCSR, csr_from_coo
from repro.launch.mesh import make_lane_mesh
from repro.runtime import faultinject as fi


class WorkerLost(RuntimeError):
    """A shard worker (one device's lane group) died mid-flush.

    Raised by the ``shard.worker`` fault site in chaos tests, and the
    exception a real multi-host transport would surface on a lost peer.
    The executors below treat it as recoverable: the dead worker's lanes
    are re-run on a surviving device (see :func:`_execute_groups`)."""

    def __init__(self, device: int, message: str = ""):
        self.device = device
        super().__init__(message or f"shard worker {device} lost")


def kill_worker_spec(device: int, *, rate: float = 1.0,
                     max_fires: Optional[int] = 1) -> fi.FaultSpec:
    """A :class:`~repro.runtime.faultinject.FaultSpec` that kills shard
    worker ``device`` (default: once) — the chaos-test building block."""
    return fi.FaultSpec(
        site="shard.worker", kind="raise", rate=rate, max_fires=max_fires,
        match={"device": device},
        exc_factory=lambda site, ctx: WorkerLost(
            ctx.get("device", device), "injected worker kill"))


# ---------------------------------------------------------------------------
# work-balanced lane assignment
# ---------------------------------------------------------------------------

def lane_works(A: BatchedCSR, B: BatchedCSR) -> np.ndarray:
    """Per-lane multiply work (sum of row_work); 0 for invalid lanes."""
    w = np.zeros(A.batch, np.int64)
    for i, a in A.lanes():
        if bool(np.asarray(B.valid)[i]):
            w[i] = int(sg.row_work(a, B[i]).sum())
    return w


def assign_lanes(works: np.ndarray, n_dev: int,
                 lanes_per_dev: Optional[int] = None) -> np.ndarray:
    """LPT greedy lane->device assignment.

    Heaviest lane first onto the least-loaded device that still has a
    free slot (shard_map needs equal lane counts per device, so each
    device takes at most ``lanes_per_dev`` = ceil(n/n_dev) lanes).
    Returns the device id per lane."""
    n = len(works)
    cap = lanes_per_dev or -(-n // max(1, n_dev))
    dev = np.zeros(n, np.int64)
    load = np.zeros(n_dev, np.int64)
    counts = np.zeros(n_dev, np.int64)
    for i in np.argsort(-np.asarray(works, np.int64), kind="stable"):
        order = np.argsort(load, kind="stable")
        d = next(int(d) for d in order if counts[d] < cap)
        dev[i] = d
        load[d] += works[i]
        counts[d] += 1
    return dev


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """A batched ExecutionPlan plus its lane->device placement.

    ``slot_of_lane[i]`` is lane i's position in the device-major slot
    layout (device d owns slots [d*lanes_per_dev, (d+1)*lanes_per_dev));
    unfilled slots hold padding (empty, invalid) lanes."""

    base: dp.ExecutionPlan
    mesh: jax.sharding.Mesh
    n_dev: int
    lanes_per_dev: int
    slot_of_lane: tuple
    works: tuple

    @property
    def n_slots(self) -> int:
        return self.n_dev * self.lanes_per_dev

    def device_loads(self) -> list:
        """Planned per-device total work (for inspection/benchmarks)."""
        loads = [0] * self.n_dev
        for i, s in enumerate(self.slot_of_lane):
            loads[s // self.lanes_per_dev] += self.works[i]
        return loads


def plan_sharded(A: BatchedCSR, B: BatchedCSR, engine: str = "auto", *,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 cache: Optional[dp.AutotuneCache] = None,
                 rules=dp.DEFAULT_HEURISTICS, **kw) -> ShardPlan:
    """Plan a batched multiply and its work-balanced lane placement."""
    works = lane_works(A, B)
    base = dp.plan_batched(A, B, engine, cache=cache, rules=rules,
                           lane_work_hint=works, **kw)
    if mesh is None:
        mesh = make_lane_mesh()
    if "lanes" not in mesh.axis_names:
        raise ValueError(f"mesh has no 'lanes' axis: {mesh.axis_names}")
    n_dev = mesh.shape["lanes"]
    lanes_per_dev = -(-A.batch // n_dev)
    dev = assign_lanes(works, n_dev, lanes_per_dev)
    next_slot = [d * lanes_per_dev for d in range(n_dev)]
    slot_of_lane = []
    for i in range(A.batch):
        slot_of_lane.append(next_slot[dev[i]])
        next_slot[dev[i]] += 1
    return ShardPlan(base=base, mesh=mesh, n_dev=n_dev,
                     lanes_per_dev=lanes_per_dev,
                     slot_of_lane=tuple(slot_of_lane),
                     works=tuple(int(w) for w in works))


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _permute_to_slots(A: BatchedCSR, sp: ShardPlan) -> BatchedCSR:
    """Re-lay a BatchedCSR into the plan's device-major slot order,
    padding unfilled slots with empty invalid lanes."""
    n_rows = A.shape[0]
    indptr = np.zeros((sp.n_slots, n_rows + 1), np.int32)
    indices = np.full((sp.n_slots, A.nnz_cap), EMPTY, np.int32)
    data = np.zeros((sp.n_slots, A.nnz_cap), np.float32)
    valid = np.zeros(sp.n_slots, bool)
    slots = np.asarray(sp.slot_of_lane, np.int64)
    indptr[slots] = np.asarray(A.indptr)
    indices[slots] = np.asarray(A.indices)
    data[slots] = np.asarray(A.data)
    valid[slots] = np.asarray(A.valid)
    return BatchedCSR(jnp.asarray(indptr), jnp.asarray(indices),
                      jnp.asarray(data), jnp.asarray(valid), A.shape)


@functools.lru_cache(maxsize=64)
def _sharded_esc_fn(mesh, cap_products: int, n_rows: int, n_cols: int):
    """One jitted shard_map per (mesh, static capacities): each device
    vmaps the ESC core over its local lane shard."""
    from jax.experimental.shard_map import shard_map

    def local(ip, ix, d, bip, bix, bd):
        return jax.vmap(sg.esc_core_impl,
                        in_axes=(0, 0, 0, 0, 0, 0, None, None, None))(
            ip, ix, d, bip, bix, bd, cap_products, n_rows, n_cols)

    spec = P("lanes")
    return jax.jit(shard_map(local, mesh=mesh, in_specs=(spec,) * 6,
                             out_specs=(spec,) * 5))


def _execute_esc_sharded(sp: ShardPlan, A: BatchedCSR, B: BatchedCSR) -> list:
    kw = sp.base.kwargs_dict
    unknown = set(kw) - {"cap_products"}
    if unknown:  # parity with the strict-kwargs single-device driver
        raise TypeError(f"esc sharded path got unexpected kwargs {unknown}")
    Ap, Bp = _permute_to_slots(A, sp), _permute_to_slots(B, sp)
    # the shard_map launch is the batched kernel for this flush: same
    # fault site as the per-group drivers in _execute_groups
    fi.fire("kernel.batched", engine="esc", lanes=A.batch)
    cap = kw["cap_products"]
    fn = _sharded_esc_fn(sp.mesh, cap, A.n_rows, B.n_cols)
    r, c, v, valid, _ = fn(Ap.indptr, Ap.indices, Ap.data,
                           Bp.indptr, Bp.indices, Bp.data)
    r, c, v, valid = map(np.asarray, (r, c, v, valid))
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    outs = []
    for i in range(A.batch):
        s = sp.slot_of_lane[i]
        outs.append(csr_from_coo(r[s][valid[s]], c[s][valid[s]],
                                 v[s][valid[s]], (A.n_rows, B.n_cols))
                    if lane_ok[i] else None)
    return outs


def _lane_select(A: BatchedCSR, idx: np.ndarray) -> BatchedCSR:
    return BatchedCSR(A.indptr[idx], A.indices[idx], A.data[idx],
                      A.valid[idx], A.shape)


def _execute_groups(sp: ShardPlan, A: BatchedCSR, B: BatchedCSR, *,
                    dead: Optional[set] = None,
                    max_worker_restarts: int = 3) -> list:
    """Host-orchestrated engines: run one device group at a time through
    the batched driver (same plan kwargs, so same static shapes).

    Worker supervision (the serving-flush generalization of
    ``runtime/fault.py::run_resilient``'s restart loop): a device group
    whose worker dies (:class:`WorkerLost` — injected via the
    ``shard.worker`` fault site, or a real transport error) marks that
    device dead and collects its lanes; after the first pass, lost lanes
    are re-run on a surviving device, with bounded restarts.  Because
    per-stream payloads are independent of which streams share a kernel
    issue, re-running a lane group elsewhere is bit-identical to the
    uninterrupted flush."""
    driver = dp.get_batch_driver(sp.base.engine)
    kw = sp.base.kwargs_dict
    slots = np.asarray(sp.slot_of_lane)
    outs: list = [None] * A.batch
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    dead = set() if dead is None else set(dead)

    def run(lanes: list, device: int) -> None:
        fi.fire("shard.worker", device=device, engine=sp.base.engine)
        idx = np.asarray(lanes)
        sub = driver(_lane_select(A, idx), _lane_select(B, idx), **kw)
        for j, i in enumerate(lanes):
            outs[i] = sub[j]

    lost: list = []
    for d in range(sp.n_dev):
        lo, hi = d * sp.lanes_per_dev, (d + 1) * sp.lanes_per_dev
        lanes = [i for i in range(A.batch)
                 if lo <= slots[i] < hi and lane_ok[i]]
        if not lanes:
            continue
        if d in dead:
            lost.extend(lanes)
            continue
        try:
            run(lanes, d)
        except WorkerLost:
            dead.add(d)
            lost.extend(lanes)
    restarts = 0
    while lost:
        alive = [d for d in range(sp.n_dev) if d not in dead]
        if not alive or restarts >= max_worker_restarts:
            raise WorkerLost(
                -1, f"{len(lost)} lanes unrecovered after {restarts} "
                    f"restarts ({sp.n_dev - len(alive)}/{sp.n_dev} "
                    f"workers dead)")
        restarts += 1
        try:
            run(lost, alive[0])
            lost = []
        except WorkerLost:
            dead.add(alive[0])
    return outs


def execute_sharded(sp: ShardPlan, A: BatchedCSR,
                    B: BatchedCSR) -> BatchedCSR:
    """Run a ShardPlan; bit-identical to ``execute_batched`` on the same
    base plan, with lanes placed per the balanced assignment."""
    dp.check_batch(A, B)
    if A.shape != sp.base.a_shape or B.shape != sp.base.b_shape \
            or A.batch != sp.base.batch:
        raise ValueError(
            f"shard plan/operand mismatch: planned {sp.base.batch}x"
            f"{sp.base.a_shape} @ {sp.base.b_shape}, got "
            f"{A.batch}x{A.shape} @ {B.shape}")
    if sp.base.engine == "esc":
        try:
            # the shard_map launch spans every device: fire the worker
            # site per participant so a kill spec matched on any device
            # id takes the whole launch down (one computation)
            for d in range(sp.n_dev):
                fi.fire("shard.worker", device=d, engine="esc")
            outs = _execute_esc_sharded(sp, A, B)
        except WorkerLost as e:
            # recover by re-running lane groups per device through the
            # batched driver, skipping the dead worker
            outs = _execute_groups(sp, A, B, dead={e.device})
    else:
        outs = _execute_groups(sp, A, B)
    return dp.assemble_batched(outs, A, B)


def spgemm_batched_sharded(A: BatchedCSR, B: BatchedCSR,
                           engine: str = "auto", *,
                           mesh: Optional[jax.sharding.Mesh] = None,
                           cache: Optional[dp.AutotuneCache] = None,
                           rules=dp.DEFAULT_HEURISTICS, **kw) -> BatchedCSR:
    """``spgemm_batched`` with lanes sharded over the device mesh.

    Exactly ``execute_sharded(plan_sharded(A, B, ...), A, B)``."""
    sp = plan_sharded(A, B, engine, mesh=mesh, cache=cache, rules=rules,
                      **kw)
    return execute_sharded(sp, A, B)
