"""Sharding rules: logical parameter/activation axes -> mesh axes.

Mesh axes (launch/mesh.py):
  pod    — data-parallel across pods (multi-pod mesh only)
  data   — data-parallel / FSDP(ZeRO) within a pod
  model  — tensor/expert parallel

Activations: batch -> (pod, data); model internals -> model.
Parameters: TP dims -> model; when cfg.fsdp, the non-TP dim additionally
shards over data (ZeRO-3 style, gathered on use by GSPMD).

A module-level mesh context makes ``constrain`` a no-op in single-device
smoke tests while giving GSPMD full placement information in the
production dry-run/launchers.
"""
from __future__ import annotations

import contextlib
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]):
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _MESH = prev


def batch_axes():
    """Mesh axes the global batch shards over (('pod','data') or ('data',))."""
    if _MESH is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in _MESH.axis_names)


def data_axis_size() -> int:
    if _MESH is None:
        return 1
    s = 1
    for a in batch_axes():
        s *= _MESH.shape[a]
    return s


def model_axis_size() -> int:
    if _MESH is None:
        return 1
    return _MESH.shape.get("model", 1)


def _axis_size(a) -> int:
    s = 1
    for name in ([a] if isinstance(a, str) else a):
        s *= _MESH.shape.get(name, 1)
    return s


def constrain(x, *spec):
    """with_sharding_constraint that degrades to identity without a mesh and
    silently drops axes that do not divide the dimension (e.g. batch=1
    decode shapes leave the data axes idle)."""
    if _MESH is None:
        return x
    clean = tuple(
        (a if a is None or x.shape[i] % _axis_size(a) == 0 else None)
        for i, a in enumerate(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*clean)))


def constrain_batch(x, *rest):
    """Shard leading (batch) dim over the data axes."""
    if _MESH is None:
        return x
    return constrain(x, batch_axes() or None, *rest)


# ---------------------------------------------------------------------------
# parameter partitioning rules
# ---------------------------------------------------------------------------

# (path regex, spec builder). ``f`` is the FSDP axis ('data' or None).
_RULES = [
    (r"embed/w$",        lambda f: ("model", f)),            # (V, D)
    (r"lm_head/w$",      lambda f: (f, "model")),            # (D, V)
    (r"(wq|wk|wv)/w$",   lambda f: (f, "model", None)),      # (D, H, hd)
    (r"(wq|wk|wv)/b$",   lambda f: ("model", None)),         # (H, hd)
    (r"wo/w$",           lambda f: ("model", None, f)),      # (H, hd, D)
    (r"(w1|w3)/w$",      lambda f: (f, "model")),            # (D, F)
    (r"w2/w$",           lambda f: ("model", f)),            # (F, D)
    (r"experts/(w1|w3)$", lambda f: ("model", f, None)),     # (E, D, F)
    (r"experts/w2$",     lambda f: ("model", None, f)),      # (E, F, D)
    (r"router/w$",       lambda f: (f, None)),               # (D, E)
    # MLA
    (r"w_dq/w$",         lambda f: (f, None)),               # (D, q_lora)
    (r"w_dkv/w$",        lambda f: (f, None)),               # (D, r+rope)
    (r"w_uq/w$",         lambda f: (None, "model", None)),   # (q_lora, H, d)
    (r"(w_uk|w_uv)/w$",  lambda f: (None, "model", None)),   # (r, H, d)
    # SSM / RG-LRU
    (r"in_proj/w$",      lambda f: (f, "model")),            # (D, inner)
    (r"out_proj/w$",     lambda f: ("model", f)),            # (inner, D)
    (r"conv/w$",         lambda f: (None, "model")),         # (k, inner)
    (r"(a_param|dt_bias|d_skip)$", lambda f: ("model",)),    # per head/channel
    (r"(a_gate|x_gate)/w$", lambda f: (f, "model")),
    # norms, scalars, everything 1-D: replicate
]


def param_spec(path: str, shape, fsdp: bool) -> P:
    f = "data" if fsdp else None
    if _MESH is not None and "data" not in _MESH.axis_names:
        f = None
    for pat, fn in _RULES:
        if re.search(pat, path):
            spec = fn(f)
            spec = spec + (None,) * (len(shape) - len(spec))
            # drop axes that would overshard tiny dims
            spec = tuple(
                (a if a is None or (_MESH is not None and
                                    shape[i] % _MESH.shape[a] == 0) else None)
                for i, a in enumerate(spec))
            return P(*spec)
    return P(*([None] * len(shape)))


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


_STACKED_RE = re.compile(r"^(g\d+|enc_g)$")


def param_shardings(params_shape, fsdp: bool):
    """Pytree of NamedShardings for a params pytree (of ShapeDtypeStructs or
    arrays). Parameters under a stacked-scan group (g<i>/enc_g) get a
    leading replicated repeat axis."""
    assert _MESH is not None, "set a mesh first"

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        stacked = bool(_STACKED_RE.match(ps.split("/")[0]))
        if stacked and len(shape) >= 1:
            spec = param_spec(ps, shape[1:], fsdp)
            spec = P(None, *spec)
        else:
            spec = param_spec(ps, shape, fsdp)
        return NamedSharding(_MESH, spec)

    return jax.tree_util.tree_map_with_path(one, params_shape)
