"""Pallas TPU kernel: mszipk.tt + mszipv.tt (fused).

Two-way merge of two sorted duplicate-free key-value chunks per stream,
with the paper's data-dependent advancement semantics:

  * a key is mergeable only if the other side holds a key >= it (the
    paper's merge bit); unmergeable keys are withheld for the next step;
  * per-side consumed counts are returned (IC0/IC1 counter registers);
  * duplicates across sides are accumulated (C-state PEs);
  * the merged output is compressed and split into a low and a high
    R-chunk (east/south output sides) with its valid length (OC0/OC1).

Because both inputs are sorted, the merge needs only the log(2R)-stage
bitonic *merge* network — the same asymptotic win the systolic zip pass
gets over a full sort.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import EMPTY
from repro.kernels import _network as net


def _stream_merge_kernel(ka_ref, va_ref, la_ref, kb_ref, vb_ref, lb_ref,
                         klo_ref, vlo_ref, khi_ref, vhi_ref,
                         ca_ref, cb_ref, ol_ref):
    ka, va = ka_ref[...], va_ref[...].astype(jnp.float32)
    kb, vb = kb_ref[...], vb_ref[...].astype(jnp.float32)
    la, lb = la_ref[...], lb_ref[...]
    r = jax.lax.broadcasted_iota(jnp.int32, ka.shape, 1)
    va_ok = r < la
    vb_ok = r < lb
    ka = jnp.where(va_ok, ka, EMPTY)
    kb = jnp.where(vb_ok, kb, EMPTY)
    va = jnp.where(va_ok, va, 0.0)
    vb = jnp.where(vb_ok, vb, 0.0)
    # merge-bit cutoff: max valid key per side (-1 when empty)
    max_a = jnp.max(jnp.where(ka != EMPTY, ka, -1), axis=-1, keepdims=True)
    max_b = jnp.max(jnp.where(kb != EMPTY, kb, -1), axis=-1, keepdims=True)
    cutoff = jnp.minimum(max_a, max_b)
    ma = (ka != EMPTY) & (ka <= cutoff)
    mb = (kb != EMPTY) & (kb <= cutoff)
    ca_ref[...] = jnp.sum(ma, axis=-1, dtype=jnp.int32)[:, None]
    cb_ref[...] = jnp.sum(mb, axis=-1, dtype=jnp.int32)[:, None]
    # bitonic concat: ascending a ++ reversed b (descending)
    cat_k = jnp.concatenate(
        [jnp.where(ma, ka, EMPTY), jnp.flip(jnp.where(mb, kb, EMPTY), -1)], -1)
    cat_v = jnp.concatenate(
        [jnp.where(ma, va, 0.0), jnp.flip(jnp.where(mb, vb, 0.0), -1)], -1)
    # zip pass: single bitonic merge network
    cat_k, cat_v = net.bitonic_merge(cat_k, cat_v)
    cat_k, cat_v = net.combine_duplicates(cat_k, cat_v)
    # compress pass
    cat_k, cat_v, n = net.compress_onehot(cat_k, cat_v)
    R = ka.shape[-1]
    klo_ref[...] = cat_k[:, :R]
    khi_ref[...] = cat_k[:, R:]
    vlo_ref[...] = cat_v[:, :R].astype(vlo_ref.dtype)
    vhi_ref[...] = cat_v[:, R:].astype(vhi_ref.dtype)
    ol_ref[...] = n[:, None]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def stream_merge_pallas(ka, va, la, kb, vb, lb, *, block_s: int = 8,
                        interpret: bool = True):
    """All chunk args (S, R); lens (S,). Returns
    (k_lo, v_lo, k_hi, v_hi, consumed_a, consumed_b, out_lens)."""
    S, R = ka.shape
    assert R & (R - 1) == 0, "R must be a power of two"
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        def pk(x):
            return jnp.pad(x, ((0, pad), (0, 0)), constant_values=EMPTY)

        def pv(x):
            return jnp.pad(x, ((0, pad), (0, 0)))

        def pl_(x):
            return jnp.pad(x, (0, pad))
        ka, va, kb, vb = pk(ka), pv(va), pk(kb), pv(vb)
        la, lb = pl_(la), pl_(lb)
    Sp = S + pad
    la2 = la[:, None].astype(jnp.int32)
    lb2 = lb[:, None].astype(jnp.int32)
    grid = (Sp // block_s,)
    kv_spec = pl.BlockSpec((block_s, R), lambda i: (i, 0))
    len_spec = pl.BlockSpec((block_s, 1), lambda i: (i, 0))
    outs = pl.pallas_call(
        _stream_merge_kernel,
        grid=grid,
        in_specs=[kv_spec, kv_spec, len_spec, kv_spec, kv_spec, len_spec],
        out_specs=[kv_spec, kv_spec, kv_spec, kv_spec,
                   len_spec, len_spec, len_spec],
        out_shape=[
            jax.ShapeDtypeStruct((Sp, R), jnp.int32),
            jax.ShapeDtypeStruct((Sp, R), va.dtype),
            jax.ShapeDtypeStruct((Sp, R), jnp.int32),
            jax.ShapeDtypeStruct((Sp, R), va.dtype),
            jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(ka, va, la2, kb, vb, lb2)
    klo, vlo, khi, vhi, ca, cb, ol = outs
    return (klo[:S], vlo[:S], khi[:S], vhi[:S],
            ca[:S, 0], cb[:S, 0], ol[:S, 0])
