"""Pallas TPU kernel: full partition merge — the zip-merge tree's primitive.

This fills the seam PR 5 left on the ``pallas`` backend: under
``backend="pallas"`` the zip-merge tree previously still ran as the XLA
rank-based union merge (``merge_tree.merge_partitions``), bouncing
partition buffers through HBM between rounds.  Here the whole merge of
two padded sorted-unique partitions is one ``pallas_call``:

payload
    Both inputs are already sorted, so the merge needs only the *cheap*
    half of the sorting machinery: concatenating the ascending A side
    with the flipped B side forms a bitonic sequence (EMPTY padding is
    the peak), and ``_network.bitonic_merge_stable`` sorts it in log(W)
    compare-exchange stages on (key, source-lane) pairs.  A-side lanes
    are numbered below B-side lanes, so cross-side duplicate keys land
    A-before-B deterministically.  Duplicates then accumulate with
    ``combine_duplicates`` and ``compress_onehot`` packs the unique
    survivors to the front.

    Bit-identity with the XLA union merge: the inputs are sorted and
    duplicate-free per side, so a duplicate run has at most 2 elements
    and the accumulated value is the single IEEE add va + vb — the same
    add the union merge performs; all other values move through
    where-selections and the exact one-hot compress, untouched.

counters
    The SparseZipper chunk-advancement state machine (merge-bit cutoff =
    min of the two R-wide front maxima; consume every key <= cutoff)
    runs per stream inside the kernel as a vectorized
    ``jax.lax.while_loop`` over read pointers — gather-free: chunk
    fronts are masked window reductions, not dynamic slices.  Per-stream
    step counts are returned and combined into per-*pair* issue counts
    outside (a pair's issue count is the max over its streams, zip_elems
    a plain sum, tails the max over streams of per-side ceil(rem/R)) —
    exactly ``merge_tree._advance_counters``'s accounting, which is
    separable per stream because a pair is active precisely while any of
    its streams is, and inactive streams present empty fronts that
    advance nothing and count zero.

Invariants: each side's keys are ascending and duplicate-free within a
row, EMPTY-padded past its ``lens`` (entries beyond lens are re-masked
here, matching the oracle's lens-trust); the concatenated network width
is a power of two (each side is padded to a shared pow2 width first).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import EMPTY
from repro.kernels import _network as net
from repro.kernels.merge_tree import MergeCounters


def merge_tile(ka, va, la, kb, vb, lb):
    """Merge two sorted-unique (N, W) tiles — pure jnp, usable inside any
    Pallas kernel body.

    ka/kb: (N, Wa)/(N, Wb) int32 ascending keys; va/vb: f32 values;
    la/lb: (N, 1) int32 valid counts.  Wa + Wb must be a power of two.
    Returns (keys (N, Wa+Wb), vals, n (N,)) with the merged uniques
    compressed to the front, cross-side duplicates accumulated —
    bit-identical to ``merge_tree._union_merge``."""
    Wa, Wb = ka.shape[-1], kb.shape[-1]
    ia = jax.lax.broadcasted_iota(jnp.int32, ka.shape, ka.ndim - 1)
    ib = jax.lax.broadcasted_iota(jnp.int32, kb.shape, kb.ndim - 1)
    ka = jnp.where(ia < la, ka, EMPTY)
    va = jnp.where(ia < la, va, 0.0)
    kb = jnp.where(ib < lb, kb, EMPTY)
    vb = jnp.where(ib < lb, vb, 0.0)
    # ascending A ++ flipped B is bitonic (EMPTY is the peak); A lanes
    # number below B lanes so equal keys order A-before-B
    cat_k = jnp.concatenate([ka, jnp.flip(kb, axis=-1)], axis=-1)
    cat_i = jnp.concatenate([ia, jnp.flip(ib + Wa, axis=-1)], axis=-1)
    cat_v = jnp.concatenate([va, jnp.flip(vb, axis=-1)], axis=-1)
    k, _, v = net.bitonic_merge_stable(cat_k, cat_i, cat_v)
    # per-side-unique inputs => duplicate runs have <= 2 elements, so the
    # log-step scan reduces to the single add va + vb
    k, v = net.combine_duplicates(k, v)
    return net.compress_onehot(k, v)


def advance_tile(ka, la, kb, lb, R: int):
    """Per-stream chunk-advancement state machine — pure jnp while_loop,
    usable inside any Pallas kernel body.

    ka/kb: (N, *) int32 ascending EMPTY-padded keys; la/lb: (N, 1) valid
    counts; R: modelled mszip chunk width.  Returns per-stream (N, 1)
    int32 (steps, zip_elems, tail_a, tail_b): lock-step advancement steps
    while both sides are live, tuples presented through the fronts, and
    leftover copy-through chunk counts per side."""
    ia = jax.lax.broadcasted_iota(jnp.int32, ka.shape, ka.ndim - 1)
    ib = jax.lax.broadcasted_iota(jnp.int32, kb.shape, kb.ndim - 1)
    z = jnp.zeros(la.shape, jnp.int32)

    def cond(state):
        pa, pb, _, _ = state
        return jnp.any((pa < la) & (pb < lb))

    def body(state):
        pa, pb, steps, zips = state
        both = (pa < la) & (pb < lb)
        ea = jnp.where(both, la, 0)  # effective lens: inactive => empty
        eb = jnp.where(both, lb, 0)
        ma = (ia >= pa) & (ia < pa + R) & (ia < ea)
        mb = (ib >= pb) & (ib < pb + R) & (ib < eb)
        # merge-bit cutoff: max valid key per front (-1 when empty)
        max_a = jnp.max(jnp.where(ma, ka, -1), axis=-1, keepdims=True)
        max_b = jnp.max(jnp.where(mb, kb, -1), axis=-1, keepdims=True)
        cutoff = jnp.minimum(max_a, max_b)
        ca = jnp.sum(ma & (ka <= cutoff), axis=-1, dtype=jnp.int32,
                     keepdims=True)
        cb = jnp.sum(mb & (kb <= cutoff), axis=-1, dtype=jnp.int32,
                     keepdims=True)
        fa_n = jnp.sum(ma, axis=-1, dtype=jnp.int32, keepdims=True)
        fb_n = jnp.sum(mb, axis=-1, dtype=jnp.int32, keepdims=True)
        return (pa + ca, pb + cb, steps + both.astype(jnp.int32),
                zips + fa_n + fb_n)

    pa, pb, steps, zips = jax.lax.while_loop(cond, body, (z, z, z, z))
    tail_a = -(-jnp.maximum(la - pa, 0) // R)
    tail_b = -(-jnp.maximum(lb - pb, 0) // R)
    return steps, zips, tail_a, tail_b


def _merge_partitions_kernel(ka_ref, va_ref, la_ref, kb_ref, vb_ref, lb_ref,
                             ok_ref, ov_ref, ol_ref, st_ref, zp_ref,
                             ta_ref, tb_ref, *, R: int, with_counters: bool):
    ka = ka_ref[...]
    va = va_ref[...].astype(jnp.float32)
    la = la_ref[...]
    kb = kb_ref[...]
    vb = vb_ref[...].astype(jnp.float32)
    lb = lb_ref[...]
    mk, mv, mn = merge_tile(ka, va, la, kb, vb, lb)
    ok_ref[...] = mk
    ov_ref[...] = mv.astype(ov_ref.dtype)
    ol_ref[...] = mn[:, None]
    if with_counters:
        steps, zips, ta, tb = advance_tile(ka, la, kb, lb, R)
        st_ref[...] = steps
        zp_ref[...] = zips
        ta_ref[...] = ta
        tb_ref[...] = tb
    else:
        z = jnp.zeros(la.shape, jnp.int32)
        st_ref[...] = z
        zp_ref[...] = z
        ta_ref[...] = z
        tb_ref[...] = z


def _next_pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


@functools.partial(jax.jit, static_argnames=("R", "pair_streams",
                                             "with_counters", "block_n",
                                             "interpret"))
def merge_partitions_pallas(ka, va, la, kb, vb, lb, *, R: int,
                            pair_streams: int | None = None,
                            with_counters: bool = True,
                            block_n: int = 8, interpret: bool = True):
    """Fully merge two padded sorted-unique partitions per stream in one
    ``pallas_call`` — same contract as ``merge_tree.merge_partitions``.

    ka/kb: (N, La)/(N, Lb) int32 keys (EMPTY padded); va/vb: values;
    la/lb: (N,) valid lengths.  R: chunk width of the modelled mszip
    issue; ``pair_streams``: lock-step group size S for the instruction
    accounting (rows [p*S, (p+1)*S) form pair p; default: one pair).

    Returns (keys (N, La+Lb), vals, lens, MergeCounters), bit-identical
    to the XLA backend including the exact counter values.
    """
    N, La = ka.shape
    Lb = kb.shape[1]
    Lo = La + Lb
    S = pair_streams or N
    la = la.astype(jnp.int32)
    lb = lb.astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    if N == 0 or Lo == 0:
        return (jnp.full((N, Lo), EMPTY, jnp.int32),
                jnp.zeros((N, Lo), va.dtype), jnp.zeros((N,), jnp.int32),
                MergeCounters(zero, zero, zero, zero))
    assert N % S == 0, f"pair_streams {S} must divide stream count {N}"
    # pad each side to a shared pow2 width so the concatenated bitonic
    # network width 2*Wm is a power of two even for ragged La/Lb
    Wm = _next_pow2(max(La, Lb, 1))
    ka = jnp.pad(ka, ((0, 0), (0, Wm - La)), constant_values=EMPTY)
    va = jnp.pad(va, ((0, 0), (0, Wm - La)))
    kb = jnp.pad(kb, ((0, 0), (0, Wm - Lb)), constant_values=EMPTY)
    vb = jnp.pad(vb, ((0, 0), (0, Wm - Lb)))
    block_n = min(block_n if not interpret else N, N)
    pad_n = (-N) % block_n
    if pad_n:
        ka = jnp.pad(ka, ((0, pad_n), (0, 0)), constant_values=EMPTY)
        va = jnp.pad(va, ((0, pad_n), (0, 0)))
        kb = jnp.pad(kb, ((0, pad_n), (0, 0)), constant_values=EMPTY)
        vb = jnp.pad(vb, ((0, pad_n), (0, 0)))
        la = jnp.pad(la, (0, pad_n))
        lb = jnp.pad(lb, (0, pad_n))
    Np = N + pad_n
    W = 2 * Wm
    grid = (Np // block_n,)
    kv_spec = pl.BlockSpec((block_n, Wm), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_n, W), lambda i: (i, 0))
    one_spec = pl.BlockSpec((block_n, 1), lambda i: (i, 0))
    kernel = functools.partial(_merge_partitions_kernel, R=R,
                               with_counters=with_counters)
    ok, ov, ol, st, zp, ta, tb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[kv_spec, kv_spec, one_spec, kv_spec, kv_spec, one_spec],
        out_specs=[out_spec, out_spec] + [one_spec] * 5,
        out_shape=[
            jax.ShapeDtypeStruct((Np, W), jnp.int32),
            jax.ShapeDtypeStruct((Np, W), va.dtype),
        ] + [jax.ShapeDtypeStruct((Np, 1), jnp.int32)] * 5,
        interpret=interpret,
    )(ka, va, la[:, None], kb, vb, lb[:, None])
    ko, vo, lo = ok[:N, :Lo], ov[:N, :Lo], ol[:N, 0]
    if with_counters:
        P = N // S
        steps_p = jnp.max(st[:N, 0].reshape(P, S), axis=1)
        n_zip = jnp.sum(steps_p, dtype=jnp.int32)
        zip_elems = jnp.sum(zp[:N, 0], dtype=jnp.int32)
        tails = (jnp.max(ta[:N, 0].reshape(P, S), axis=1)
                 + jnp.max(tb[:N, 0].reshape(P, S), axis=1))
        cnt = MergeCounters(n_zip, zip_elems, 2 * n_zip,
                            n_zip + jnp.sum(tails, dtype=jnp.int32))
    else:
        cnt = MergeCounters(zero, zero, zero, zero)
    return ko, vo, lo, cnt
