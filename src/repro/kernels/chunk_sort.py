"""Pallas TPU kernel: the fused pipeline's chunk-sort stage.

Sorts ALL (N, R) = (S*C, R) chunks of a work bucket in one ``pallas_call``
issue — the sort stage ``chunk_sort_partitions`` feeds into the
device-resident zip-merge tree.  Unlike ``stream_sort_pallas`` (the
host-tier mssort kernel, whose duplicate accumulation is a log-step tree
scan), this kernel is **bit-identical** to the XLA oracle
(``ref.stream_sort_ref`` / ``merge_tree.sort_chunks_linear``):

  * the sort is a bitonic network over the R lane dimension made *stable*
    by comparing (key, source-lane) pairs lexicographically
    (``_network.bitonic_sort_stable``), so ties keep product order
    exactly like a stable argsort;
  * duplicate values accumulate in a left-to-right linear association
    (an R-step sequential run prefix, the same adds in the same order as
    ``segment_sum``'s index-order accumulation) — a tree reduction would
    round differently;
  * the compress pass routes each surviving tuple through a one-hot MXU
    matmul with exactly one unit coefficient per output lane, which moves
    keys (16-bit split) and values bit-exactly.

Invariants: R must be a power of two (bitonic network width); input keys
beyond ``lens`` may be garbage (they are masked to EMPTY first); valid
keys are < 2**31 - 1 so EMPTY is a strict upper bound and the 16-bit
compress split is exact.

One program sorts a (BLOCK_N, R) tile held in VMEM; the grid walks blocks
of chunks, so a whole bucket's S*C chunks are one kernel issue.  The tile
body is exposed as :func:`sort_tile` so the single-kernel fused bucket
pipeline (``kernels/fused_bucket.py``) can run the identical sort stage
inside its own ``pallas_call``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import EMPTY
from repro.kernels import _network as net


def sort_tile(keys, vals, lens):
    """Sort/combine/compress an (N, R) tile of chunks — pure jnp, usable
    inside any Pallas kernel body.

    keys: (N, R) int32, vals: (N, R) f32, lens: (N, 1) int32 valid
    counts.  Returns (keys (N, R), vals (N, R), n (N,)) with the unique
    sorted keys compressed to the front (EMPTY/0 beyond n), duplicate
    values accumulated left-to-right — bit-identical to
    ``ref.stream_sort_ref`` / ``merge_tree.sort_chunks_linear``."""
    R = keys.shape[-1]
    r = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    valid = r < lens
    k = jnp.where(valid, keys, EMPTY)
    v = jnp.where(valid, vals, 0.0)
    # stable ascending sort (ties keep product order, like stable argsort)
    k, _, v = net.bitonic_sort_stable(k, r, v)
    # linear run accumulation: acc[i] = left-to-right prefix of i's run;
    # adding the predecessor's finished prefix keeps the float association
    # linear, bit-identical to segment_sum's index-order adds
    start = k != net.shift_right(k, 1, EMPTY)
    s = jnp.where(start, r, 0)
    d = 1
    while d < R:  # Hillis-Steele max-scan: start index of each run
        s = jnp.maximum(s, net.shift_right(s, d, 0))
        d *= 2
    run_pos = r - s
    acc = v
    for d in range(1, R):
        shifted = net.shift_right(acc, 1, 0.0)
        acc = jnp.where(run_pos == d, shifted + v, acc)
    # keep the run total (last element of each run), then compress
    is_last = (k != net.shift_left(k, 1, EMPTY)) & (k != EMPTY)
    k2 = jnp.where(is_last, k, EMPTY)
    v2 = jnp.where(is_last, acc, 0.0)
    return net.compress_onehot(k2, v2)


def _chunk_sort_kernel(keys_ref, vals_ref, lens_ref, ok_ref, ov_ref, ol_ref):
    k3, v3, n = sort_tile(keys_ref[...], vals_ref[...].astype(jnp.float32),
                          lens_ref[...])
    ok_ref[...] = k3
    ov_ref[...] = v3.astype(ov_ref.dtype)
    ol_ref[...] = n[:, None]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def chunk_sort_pallas(keys, vals, lens, *, block_n: int = 8,
                      interpret: bool = True):
    """Sort/combine/compress all N key-value chunks in one kernel issue.

    keys: (N, R) int32; vals: (N, R) float; lens: (N,) int32.  R must be
    a power of two.  Returns (out_keys, out_vals, out_lens), bit-identical
    to ``ref.stream_sort_ref`` on the same inputs."""
    N, R = keys.shape
    assert R & (R - 1) == 0, "R must be a power of two"
    if N == 0:  # zero chunks: same empty outputs as the xla oracle
        return keys, vals, lens.astype(jnp.int32)
    block_n = min(block_n, N)
    pad = (-N) % block_n
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=EMPTY)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        lens = jnp.pad(lens, (0, pad))
    Np = N + pad
    lens2 = lens[:, None].astype(jnp.int32)
    grid = (Np // block_n,)
    kv_spec = pl.BlockSpec((block_n, R), lambda i: (i, 0))
    len_spec = pl.BlockSpec((block_n, 1), lambda i: (i, 0))
    ok, ov, ol = pl.pallas_call(
        _chunk_sort_kernel,
        grid=grid,
        in_specs=[kv_spec, kv_spec, len_spec],
        out_specs=[kv_spec, kv_spec, len_spec],
        out_shape=[
            jax.ShapeDtypeStruct((Np, R), jnp.int32),
            jax.ShapeDtypeStruct((Np, R), vals.dtype),
            jax.ShapeDtypeStruct((Np, 1), jnp.int32),
        ],
        interpret=interpret,
    )(keys, vals, lens2)
    return ok[:N], ov[:N], ol[:N, 0]
