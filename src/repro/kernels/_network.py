"""TPU-native building blocks shared by the zipper kernels.

The paper routes keys through a 16x16 systolic array of compare-and-route
PEs in two passes (sort/merge, then compress). On TPU the equivalent
data-parallel structures are:

  * compare-exchange networks over the 128-wide lane dimension, where the
    XOR-partner shuffle at stride j is a reshape+reverse (no gather);
  * log-step Hillis-Steele scans for duplicate accumulation / prefix sums;
  * a one-hot matmul for the compress pass — we re-use the matrix unit to
    apply the routing permutation, the direct analogue of SparseZipper
    re-using the dense-GEMM systolic array for data routing. Keys are
    split into two 16-bit halves so the f32 matmul is exact.

All helpers are pure jnp on (S, W) tiles and run unchanged inside Pallas
kernel bodies (interpret=True on CPU, MXU/VPU lowering on TPU).

Invariants the kernels built from these blocks rely on:

  * network widths are powers of two — ``xor_shuffle`` reshapes the lane
    axis into (W/2j, 2, j) groups, so every stride j must divide W;
  * EMPTY (INT32_MAX) compares greater than every valid key, so
    EMPTY-padded rows sort/merge with the padding parked at the end and
    an ascending-prefix ++ flipped-sorted-suffix concatenation of two
    padded rows is a valid bitonic sequence for ``bitonic_merge``;
  * the ``*_stable`` variants compare (key, source-lane) pairs
    lexicographically.  Source lanes are unique per row, so the order is
    total and ties keep input order — a *stable* sort/merge, which is
    what makes duplicate-value accumulation order deterministic and
    bit-reproducible across backends;
  * ``compress_onehot`` is exact because keys are split into two 16-bit
    halves before the f32 one-hot matmul (f32 holds integers < 2**24
    exactly) and each output lane receives exactly one unit coefficient,
    so values are moved, not recombined.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import EMPTY


def xor_shuffle(x, j):
    """Exchange lane groups: out[..., i] = x[..., i ^ j] (j power of two)."""
    W = x.shape[-1]
    lead = x.shape[:-1]
    y = x.reshape(*lead, W // (2 * j), 2, j)
    y = jnp.flip(y, axis=-2)
    return y.reshape(*lead, W)


def _lane_iota(shape):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dimension=len(shape) - 1)


def _compare_exchange(keys, carried, j, asc):
    """One compare-exchange stage at stride j. ``asc`` is a bool array
    (per lane) giving the sort direction of each bitonic block."""
    idx = _lane_iota(keys.shape)
    is_lower = (idx & j) == 0
    pk = xor_shuffle(keys, j)
    gt, lt = keys > pk, keys < pk
    take_partner = jnp.where(asc, jnp.where(is_lower, gt, lt),
                             jnp.where(is_lower, lt, gt))
    new_keys = jnp.where(take_partner, pk, keys)
    new_carried = [jnp.where(take_partner, xor_shuffle(c, j), c) for c in carried]
    return new_keys, new_carried


def bitonic_sort(keys, *carried):
    """Full ascending bitonic sort of each row; carried arrays follow keys."""
    W = keys.shape[-1]
    carried = list(carried)
    idx = _lane_iota(keys.shape)
    k = 2
    while k <= W:
        asc = (idx & k) == 0  # at k == W this is all-True (idx < W)
        j = k // 2
        while j >= 1:
            keys, carried = _compare_exchange(keys, carried, j, asc)
            j //= 2
        k *= 2
    return (keys, *carried)


def bitonic_merge(keys, *carried):
    """Sort a bitonic row (ascending prefix + descending suffix) ascending.
    This is the cheap log(W)-stage network the mszip instructions exploit:
    both inputs are already sorted."""
    W = keys.shape[-1]
    carried = list(carried)
    asc = jnp.ones(keys.shape, bool)
    j = W // 2
    while j >= 1:
        keys, carried = _compare_exchange(keys, carried, j, asc)
        j //= 2
    return (keys, *carried)


def compare_exchange_stable(keys, idx, vals, j, asc):
    """One compare-exchange stage at stride j on (key, idx) pairs.

    ``idx`` is the original lane of each element — unique per row — so the
    lexicographic order is total and the network reproduces a *stable*
    ascending sort of the keys.  ``vals`` follows the pairs."""
    lane = _lane_iota(keys.shape)
    is_lower = (lane & j) == 0
    pk = xor_shuffle(keys, j)
    pi = xor_shuffle(idx, j)
    gt = (keys > pk) | ((keys == pk) & (idx > pi))
    lt = (keys < pk) | ((keys == pk) & (idx < pi))
    take_partner = jnp.where(asc, jnp.where(is_lower, gt, lt),
                             jnp.where(is_lower, lt, gt))
    return (jnp.where(take_partner, pk, keys),
            jnp.where(take_partner, pi, idx),
            jnp.where(take_partner, xor_shuffle(vals, j), vals))


def bitonic_sort_stable(keys, idx, vals):
    """Full ascending stable bitonic sort of each row by (key, idx)."""
    W = keys.shape[-1]
    lane = _lane_iota(keys.shape)
    k = 2
    while k <= W:
        asc = (lane & k) == 0
        j = k // 2
        while j >= 1:
            keys, idx, vals = compare_exchange_stable(keys, idx, vals, j,
                                                      asc)
            j //= 2
        k *= 2
    return keys, idx, vals


def bitonic_merge_stable(keys, idx, vals):
    """Sort a bitonic row ascending by (key, idx) pairs — the cheap
    log(W)-stage half of the stable network for inputs that are already
    an ascending prefix ++ descending suffix (two sorted runs, the second
    flipped).  This is the network shape the mszip instructions exploit:
    merging two sorted chunks costs log(W) stages, not log^2(W)."""
    W = keys.shape[-1]
    asc = jnp.ones(keys.shape, bool)
    j = W // 2
    while j >= 1:
        keys, idx, vals = compare_exchange_stable(keys, idx, vals, j, asc)
        j //= 2
    return keys, idx, vals


def shift_right(x, d, fill):
    """Lane-shift right by d with fill (x[..., i] <- x[..., i-d])."""
    pad = jnp.full(x.shape[:-1] + (d,), fill, x.dtype)
    return jnp.concatenate([pad, x[..., :-d]], axis=-1)


def shift_left(x, d, fill):
    pad = jnp.full(x.shape[:-1] + (d,), fill, x.dtype)
    return jnp.concatenate([x[..., d:], pad], axis=-1)


def segmented_run_sum(keys, vals):
    """Inclusive segmented scan: vals summed within runs of equal keys.
    Returns scan such that the LAST lane of each run holds the run total."""
    W = keys.shape[-1]
    flag = (keys == shift_right(keys, 1, -1)) & (keys != EMPTY)
    v = vals
    d = 1
    while d < W:
        v = v + jnp.where(flag, shift_right(v, d, 0), 0)
        flag = flag & shift_right(flag, d, False)
        d *= 2
    return v


def lane_cumsum(x):
    """Inclusive prefix sum along lanes via log-step shifts (int32)."""
    W = x.shape[-1]
    s = x
    d = 1
    while d < W:
        s = s + shift_right(s, d, 0)
        d *= 2
    return s


def combine_duplicates(keys, vals):
    """After an ascending sort: accumulate duplicate keys onto the last
    element of each run; earlier elements become EMPTY/0 ("d" outputs in
    the paper's sort pass)."""
    totals = segmented_run_sum(keys, vals)
    is_last = (keys != shift_left(keys, 1, -1)) & (keys != EMPTY)
    k = jnp.where(is_last, keys, EMPTY)
    v = jnp.where(is_last, totals, 0)
    return k, v


def compress_onehot(keys, vals, out_width=None):
    """Compress pass: route valid (key, val) lanes to the front, preserving
    order, using one-hot matmuls (the MXU plays the systolic array's
    routing role). Exact for keys < 2**31 via 16-bit split.

    Returns (keys_out, vals_out, n_valid) with keys_out width ``out_width``
    (default: same as input)."""
    W = keys.shape[-1]
    out_w = out_width or W
    valid = keys != EMPTY
    pos = lane_cumsum(valid.astype(jnp.int32)) - 1  # destination lane
    pos = jnp.where(valid, pos, out_w)  # park invalid out of range
    dest = _lane_iota(keys.shape[:-1] + (out_w,))
    onehot = (pos[..., :, None] == dest[..., None, :]).astype(jnp.float32)
    k_hi = jnp.right_shift(keys, 16).astype(jnp.float32)
    k_lo = jnp.bitwise_and(keys, 0xFFFF).astype(jnp.float32)
    hit = jnp.einsum("...sw,...swp->...sp", jnp.ones_like(k_hi), onehot)
    o_hi = jnp.einsum("...sw,...swp->...sp", k_hi, onehot)
    o_lo = jnp.einsum("...sw,...swp->...sp", k_lo, onehot)
    o_v = jnp.einsum("...sw,...swp->...sp", vals.astype(jnp.float32), onehot)
    keys_out = jnp.left_shift(o_hi.astype(jnp.int32), 16) | o_lo.astype(jnp.int32)
    keys_out = jnp.where(hit > 0, keys_out, EMPTY)
    vals_out = jnp.where(hit > 0, o_v, 0).astype(vals.dtype)
    n_valid = jnp.sum(valid, axis=-1, dtype=jnp.int32)
    return keys_out, vals_out, n_valid
