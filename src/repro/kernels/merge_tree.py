"""Device-resident zip-merge tree: the lock-step merge loop as a jitted scan.

The host spz driver (``core/spgemm.py``) runs the paper's data-dependent
chunk advancement as a Python ``while`` loop — one tiny ``stream_merge``
dispatch per chunk with numpy gather/scatter marshaling in between, which
is exactly the overhead SparseZipper keeps inside the matrix unit.  This
module moves that state machine onto the device:

``merge_partitions``
    Fully merge two padded (N, L) sorted-unique partitions per stream in
    one jittable computation.  The per-stream read pointers ``pa``/``pb``
    run the chunk-advancement state machine under ``jax.lax.while_loop``
    (dynamic-slice chunk fronts, pointers as device state — the
    stream-register analogue of Sparse Stream Semantic Registers), which
    yields the SparseZipper instruction counters.  The merged *payload*
    is computed by a rank-based union merge (gathers + row-wise
    searchsorted, no data-dependent loop): because two sorted
    duplicate-free streams always consume equal keys in the same
    lock-step step — a key can only be mergeable once the other side's
    front has reached it — the chunk loop's packed output is provably
    byte-identical to the one-shot union, so values never ride through
    the sequential loop.

``zip_merge_tree``
    The full tree over C = 2**k sorted R-chunk partitions: each round
    stacks all partition pairs onto the stream axis and merges them with
    one ``merge_partitions`` call, halving the partition count until one
    (S, C*R) partition survives.  Rounds are unrolled at trace time (C is
    static), so the tree is one jittable function.

Counter semantics match the host driver's ``SpzStats`` accounting: an
mszip "issue" is one lock-step step of one partition pair across its S
streams, counted only while that pair has active streams — identical to
the host loop's per-iteration counts, because inactive pairs present
empty fronts and advance nothing.

This module is the **XLA oracle** for the merge stage: the native Pallas
kernels (``kernels/merge_partitions.py``, ``kernels/fused_bucket.py``)
are verified bit-identical against it, counters included
(``tests/test_backend_parity.py``).

Invariants shared by every merge implementation in this repo:

  * merge inputs are per-row ascending and duplicate-free, EMPTY-padded
    past their ``lens`` — EMPTY (INT32_MAX) must sort after every valid
    key, which is what lets searchsorted/bitonic machinery ignore the
    padding (garbage past ``lens`` is NOT tolerated by the union merge's
    rank arithmetic);
  * R (the mszip chunk width) is a power of two, and the zip-merge tree
    needs C = L/R partitions with C a power of two (trailing empty
    partitions merge as no-ops and cost no zip issues);
  * the merge *payload* is rank/selection-based — values are gathered or
    where-selected, and a cross-side duplicate accumulates as the single
    IEEE add ``va + vb``.  Because each side is duplicate-free, that is
    the only float operation the merge performs, which is why every
    backend produces bit-identical values.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.formats import EMPTY


class MergeCounters(NamedTuple):
    """SparseZipper dynamic-instruction counters, as device int32 scalars."""

    n_mszip: jnp.ndarray      # zip-instruction issues
    zip_elems: jnp.ndarray    # key-value tuples moved through merge
    chunk_loads: jnp.ndarray  # mlxe.t analogue (chunk fronts built)
    chunk_stores: jnp.ndarray # msxe.t analogue


def _rowwise_searchsorted(a, q, side="left"):
    """Per-row searchsorted: a (N, W) sorted rows, q (N, Q) queries."""
    return jax.vmap(functools.partial(jnp.searchsorted, side=side))(a, q)


def _union_merge(ka, va, la, kb, vb, lb):
    """One-shot merge of two sorted duplicate-free padded partitions.

    Equal keys across sides accumulate as ``va + vb`` (the index order the
    chunk-level mszip kernel uses); all other values pass through
    untouched, so the result is byte-identical to driving the chunk loop.
    Gathers and row-wise searchsorted only — no scatters, no sorts.

    Returns (keys (N, La+Lb), vals, lens)."""
    N, La = ka.shape
    Lb = kb.shape[1]
    Lo = La + Lb
    ar = jnp.arange(La, dtype=jnp.int32)
    br = jnp.arange(Lb, dtype=jnp.int32)
    a_ok = ar[None, :] < la[:, None]
    b_ok = br[None, :] < lb[:, None]
    # cross-side duplicate detection (valid keys are never EMPTY and the
    # EMPTY padding sorts after every valid key)
    jb = _rowwise_searchsorted(kb, ka).astype(jnp.int32)
    jb_c = jnp.minimum(jb, Lb - 1)
    amatch = a_ok & (jb < lb[:, None]) & \
        (jnp.take_along_axis(kb, jb_c, axis=1) == ka)
    ia = _rowwise_searchsorted(ka, kb).astype(jnp.int32)
    ia_c = jnp.minimum(ia, La - 1)
    bmatch = b_ok & (ia < la[:, None]) & \
        (jnp.take_along_axis(ka, ia_c, axis=1) == kb)
    # a absorbs its duplicate's value; dropped b keeps the a slot position
    va2 = jnp.where(amatch, va + jnp.take_along_axis(vb, jb_c, axis=1), va)
    excl_a = jnp.cumsum(amatch, axis=1, dtype=jnp.int32) - amatch
    excl_b = jnp.cumsum(bmatch, axis=1, dtype=jnp.int32) - bmatch
    # output rank: position among the merged uniques
    pos_a = jnp.where(a_ok, ar[None, :] + jb - excl_a, Lo)
    pos_b_surv = br[None, :] + ia - excl_b
    pos_b = jnp.where(b_ok,
                      jnp.where(bmatch,
                                jnp.take_along_axis(pos_a, ia_c, axis=1),
                                pos_b_surv),
                      Lo)
    # invert the (strictly increasing over valid slots) rank maps with
    # searchsorted — a gather-only compaction
    m = jnp.broadcast_to(jnp.arange(Lo, dtype=jnp.int32)[None, :], (N, Lo))
    qa = _rowwise_searchsorted(pos_a, m).astype(jnp.int32)
    qa_c = jnp.minimum(qa, La - 1)
    is_a = (qa < La) & (jnp.take_along_axis(pos_a, qa_c, axis=1) == m)
    qb = _rowwise_searchsorted(pos_b, m).astype(jnp.int32)
    qb_c = jnp.minimum(qb, Lb - 1)
    is_b = ~is_a & (qb < Lb) & \
        (jnp.take_along_axis(pos_b, qb_c, axis=1) == m)
    out_k = jnp.where(is_a, jnp.take_along_axis(ka, qa_c, axis=1),
                      jnp.where(is_b, jnp.take_along_axis(kb, qb_c, axis=1),
                                EMPTY))
    out_v = jnp.where(is_a, jnp.take_along_axis(va2, qa_c, axis=1),
                      jnp.where(is_b, jnp.take_along_axis(vb, qb_c, axis=1),
                                0.0))
    out_len = la + lb - jnp.sum(amatch, axis=1, dtype=jnp.int32)
    return out_k, out_v, out_len


def sort_chunks_linear(keys, vals, lens):
    """Scatter-free chunk sort, byte-identical to ``ref.stream_sort_ref``.

    Same contract (sort each (N, R) chunk, accumulate duplicate keys,
    compress uniques to the front) and the same left-to-right value
    accumulation order, but built for the device-resident pipeline: one
    stable argsort, an R-step sequential run prefix (adding the
    predecessor's finished prefix keeps the float association linear,
    exactly like segment_sum's index-order adds), and a searchsorted
    compaction — no vmapped segment_sum scatter, no second sort.
    """
    N, R = keys.shape
    r = jnp.arange(R, dtype=jnp.int32)
    in_ok = r[None, :] < lens[:, None]
    k0 = jnp.where(in_ok, keys, EMPTY)
    v0 = jnp.where(in_ok, vals, 0)
    order = jnp.argsort(k0, axis=-1)  # stable: ties keep product order
    k = jnp.take_along_axis(k0, order, axis=-1)
    v = jnp.take_along_axis(v0, order, axis=-1)
    prev = jnp.concatenate([jnp.full_like(k[:, :1], EMPTY), k[:, :-1]],
                           axis=-1)
    start = k != prev
    start_idx = jax.lax.cummax(jnp.where(start, r[None, :], 0), axis=1)
    run_pos = r[None, :] - start_idx
    acc = v
    for d in range(1, R):
        shifted = jnp.concatenate([jnp.zeros_like(acc[:, :1]),
                                   acc[:, :-1]], axis=-1)
        acc = jnp.where(run_pos == d, shifted + v, acc)
    nxt = jnp.concatenate([k[:, 1:], jnp.full_like(k[:, :1], EMPTY)],
                          axis=-1)
    is_last = (k != nxt) & (k != EMPTY)
    csum = jnp.cumsum(is_last, axis=-1, dtype=jnp.int32)
    idx = _rowwise_searchsorted(
        csum, jnp.broadcast_to(r[None, :] + 1, (N, R))).astype(jnp.int32)
    idx_c = jnp.minimum(idx, R - 1)
    out_ok = r[None, :] < csum[:, -1:]
    out_k = jnp.where(out_ok, jnp.take_along_axis(k, idx_c, axis=-1), EMPTY)
    out_v = jnp.where(out_ok, jnp.take_along_axis(acc, idx_c, axis=-1), 0)
    return out_k, out_v, csum[:, -1]


def _front_keys(K, lens, ptr, R: int):
    """(N, R) key chunk front at ``ptr`` (EMPTY past the effective lens)."""
    L = K.shape[1]
    n = jnp.clip(lens - ptr, 0, R)
    idx = jnp.clip(ptr[:, None] + jnp.arange(R, dtype=jnp.int32)[None, :],
                   0, max(L - 1, 0))
    ok = jnp.arange(R, dtype=jnp.int32)[None, :] < n[:, None]
    return jnp.where(ok, jnp.take_along_axis(K, idx, axis=1), EMPTY), n


def _advance_counters(ka, la, kb, lb, *, R: int, pair_streams: int):
    """Run the lock-step chunk-advancement state machine on pointers only.

    This is the data-dependent ``jax.lax.while_loop``: per-stream read
    pointers pa/pb advance by the mszip consumed counts (all keys <= the
    merge-bit cutoff) until one side of every stream is exhausted.

    Returns (steps (P,), zip_elems (), tails (P, 2)) — per-pair issue
    counts, total tuples presented, and per-pair/per-side copy-through
    tail stores.  Per-pair vectors (rather than pre-summed scalars) let a
    caller that split one lock-step group across several kernel calls
    reconstruct the group counters exactly: a pair's issue count is the
    max per-stream step count, so group steps = elementwise max over the
    splits, while zip_elems is a plain per-stream sum."""
    N = ka.shape[0]
    S = pair_streams
    P = N // S

    def cond(state):
        pa, pb, _, _ = state
        return jnp.any((pa < la) & (pb < lb))

    def body(state):
        pa, pb, steps, zip_elems = state
        both = (pa < la) & (pb < lb)
        fa_k, fa_n = _front_keys(ka, jnp.where(both, la, 0), pa, R)
        fb_k, fb_n = _front_keys(kb, jnp.where(both, lb, 0), pb, R)
        # merge-bit cutoff: max valid key per side (-1 when empty)
        max_a = jnp.max(jnp.where(fa_k != EMPTY, fa_k, -1), axis=1)
        max_b = jnp.max(jnp.where(fb_k != EMPTY, fb_k, -1), axis=1)
        cutoff = jnp.minimum(max_a, max_b)
        ca = jnp.sum((fa_k != EMPTY) & (fa_k <= cutoff[:, None]), axis=1,
                     dtype=jnp.int32)
        cb = jnp.sum((fb_k != EMPTY) & (fb_k <= cutoff[:, None]), axis=1,
                     dtype=jnp.int32)
        steps = steps + jnp.any(both.reshape(P, S), axis=1).astype(jnp.int32)
        zip_elems = zip_elems + jnp.sum(fa_n + fb_n, dtype=jnp.int32)
        return pa + ca, pb + cb, steps, zip_elems

    z = jnp.zeros((N,), jnp.int32)
    pa, pb, steps, zip_elems = jax.lax.while_loop(
        cond, body, (z, z, jnp.zeros((P,), jnp.int32),
                     jnp.zeros((), jnp.int32)))
    # copy-through tail stores (one msxe.t per R-chunk, lock-step per pair)
    tails = []
    for lens, ptr in ((la, pa), (lb, pb)):
        rem = jnp.maximum(lens - ptr, 0)
        tails.append(jnp.max(-(-rem.reshape(P, S) // R), axis=1))
    return steps, zip_elems, jnp.stack(tails, axis=1).astype(jnp.int32)


def merge_partitions(ka, va, la, kb, vb, lb, *, R: int,
                     pair_streams: int | None = None,
                     with_counters: bool = True):
    """Fully merge two padded sorted-unique partitions per stream.

    ka/kb: (N, La)/(N, Lb) int32 keys (EMPTY padded); va/vb: values;
    la/lb: (N,) valid lengths.  R: chunk width of the modelled mszip
    issue.  ``pair_streams``: lock-step group size S for instruction
    accounting — rows [p*S, (p+1)*S) form partition pair p, and a zip
    issue is counted per *pair* per advancement step while that pair is
    active (the host driver's ``_merge_round`` semantics).  Default: all
    N rows are one pair.  ``with_counters=False`` skips the pointer state
    machine and returns zero counters (the payload does not depend on
    it).

    Returns (keys (N, La+Lb), vals, lens, MergeCounters).  Jittable with
    static R/pair_streams/with_counters.
    """
    N = ka.shape[0]
    S = pair_streams or N
    assert N % S == 0, f"pair_streams {S} must divide stream count {N}"
    la = la.astype(jnp.int32)
    lb = lb.astype(jnp.int32)
    ko, vo, lo = _union_merge(ka, va, la, kb, vb, lb)
    if with_counters:
        steps, zip_elems, tails = _advance_counters(ka, la, kb, lb, R=R,
                                                    pair_streams=S)
        n_zip = jnp.sum(steps, dtype=jnp.int32)
        cnt = MergeCounters(n_zip, zip_elems, 2 * n_zip,
                            n_zip + jnp.sum(tails, dtype=jnp.int32))
    else:
        z = jnp.zeros((), jnp.int32)
        cnt = MergeCounters(z, z, z, z)
    return ko, vo, lo, cnt


def zip_merge_tree(keys, vals, lens, *, R: int, with_counters: bool = True,
                   detailed: bool = False):
    """Zip-merge tree over C = 2**k sorted R-chunk partitions, on device.

    keys/vals: (S, C, R) sorted-unique partitions (trailing partitions may
    be empty — they merge as no-ops and cost no zip issues); lens: (S, C).
    Each round stacks all partition pairs onto the stream axis and merges
    them in one shot, so the tree is log2(C) jittable rounds.

    Returns (keys (S, C*R), vals, lens (S,), counters) where counters is
    a MergeCounters of summed scalars, or — with ``detailed=True`` — a
    tuple with one (steps (P,), zip_elems (), tails (P, 2)) entry per
    round, letting a caller that split a lock-step group across several
    calls rebuild the group-exact issue counts (elementwise max over
    splits for steps/tails, sum for zip_elems).
    """
    S, C, _ = keys.shape
    assert C & (C - 1) == 0, f"partition count {C} must be a power of two"
    parts = [(keys[:, c], vals[:, c], lens[:, c].astype(jnp.int32))
             for c in range(C)]
    rounds = []
    cnt = MergeCounters(*(jnp.zeros((), jnp.int32) for _ in range(4)))
    while len(parts) > 1:
        half = len(parts) // 2
        ka = jnp.concatenate([parts[2 * j][0] for j in range(half)], axis=0)
        va = jnp.concatenate([parts[2 * j][1] for j in range(half)], axis=0)
        la = jnp.concatenate([parts[2 * j][2] for j in range(half)], axis=0)
        kb = jnp.concatenate([parts[2 * j + 1][0] for j in range(half)], axis=0)
        vb = jnp.concatenate([parts[2 * j + 1][1] for j in range(half)], axis=0)
        lb = jnp.concatenate([parts[2 * j + 1][2] for j in range(half)], axis=0)
        ko, vo, lo = _union_merge(ka, va, la, kb, vb, lb)
        if with_counters or detailed:
            steps, zip_elems, tails = _advance_counters(ka, la, kb, lb, R=R,
                                                        pair_streams=S)
            rounds.append((steps, zip_elems, tails))
            n_zip = jnp.sum(steps, dtype=jnp.int32)
            round_cnt = MergeCounters(n_zip, zip_elems, 2 * n_zip,
                                      n_zip + jnp.sum(tails,
                                                      dtype=jnp.int32))
            cnt = MergeCounters(*(a + b for a, b in zip(cnt, round_cnt)))
        parts = [(ko[j * S:(j + 1) * S], vo[j * S:(j + 1) * S],
                  lo[j * S:(j + 1) * S]) for j in range(half)]
    k, v, ln = parts[0]
    return k, v, ln, (tuple(rounds) if detailed else cnt)
