"""Pallas TPU kernel: grouped (per-expert) matmul — the MoE FFN hot spot.

Rows of x are grouped by expert (zipper-sorted upstream: group g owns rows
[offsets[g], offsets[g+1])). Each row tile multiplies only its expert's
weight tile; the tile -> expert map is a scalar-prefetch operand so the
weight BlockSpec index_map can select the right expert block (the
MegaBlocks trick, TPU-style). Rows past the last group are zeroed.

Restriction (documented): group boundaries are rounded to the row-tile
size by the caller (capacity-padded zipper dispatch guarantees this —
capacities are multiples of 8 and padded rows multiply by zero weights).
Oracle: ref.grouped_matmul_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(tile_gid_ref, x_ref, w_ref, o_ref, *, bt):
    t = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (bt, D)
    w = w_ref[0].astype(jnp.float32)            # (D, F)
    valid = tile_gid_ref[t] >= 0
    out = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = jnp.where(valid, out, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def grouped_matmul_pallas(x, w, group_sizes, *, bt: int = 8,
                          interpret: bool = True):
    """x: (T, D) rows grouped by expert; w: (E, D, F);
    group_sizes: (E,) int32 (sum <= T, each a multiple of bt).
    Returns (T, F)."""
    T, D = x.shape
    E, _, F = w.shape
    pad = (-T) % bt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Tp = T + pad
    nt = Tp // bt
    # tile -> expert id (-1 for tiles past the last group)
    ends = jnp.cumsum(group_sizes)
    tile_starts = jnp.arange(nt, dtype=jnp.int32) * bt
    gid = jnp.searchsorted(ends, tile_starts, side="right").astype(jnp.int32)
    tile_gid = jnp.where(tile_starts < ends[-1], gid, -1)

    out = pl.pallas_call(
        functools.partial(_gmm_kernel, bt=bt),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nt,),
            in_specs=[
                pl.BlockSpec((bt, D), lambda t, gids: (t, 0)),
                pl.BlockSpec((1, D, F),
                             lambda t, gids: (jnp.maximum(gids[t], 0), 0, 0)),
            ],
            out_specs=pl.BlockSpec((bt, F), lambda t, gids: (t, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((Tp, F), x.dtype),
        interpret=interpret,
    )(tile_gid, x, w)
    return out[:T]
