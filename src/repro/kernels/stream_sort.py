"""Pallas TPU kernel: mssortk.tt + mssortv.tt (fused).

Sorts S independent key-value chunks (one per sublane row, the analogue of
one stream per tile-register row), accumulates duplicate keys, and
compresses valid tuples to the front — the paper's two-pass systolic
execution mapped onto VPU compare-exchange networks plus an MXU one-hot
routing matmul (see kernels/_network.py).

Grid: one program per block of S_BLK streams. The whole (S_BLK, R) tile of
keys and values lives in VMEM; R <= 512 and S_BLK * R * (4+4+4+4) bytes per
tile keeps the working set well under the ~16 MB VMEM budget (default
8 x 128 tile = 16 KB keys + 16 KB values + one (8,128,128) f32 routing
one-hot = 512 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import EMPTY
from repro.kernels import _network as net


def _stream_sort_kernel(keys_ref, vals_ref, lens_ref,
                        ok_ref, ov_ref, ol_ref):
    keys = keys_ref[...]
    vals = vals_ref[...].astype(jnp.float32)
    lens = lens_ref[...]  # (S_BLK, 1)
    r = jax.lax.broadcasted_iota(jnp.int32, keys.shape, 1)
    valid = r < lens
    keys = jnp.where(valid, keys, EMPTY)
    vals = jnp.where(valid, vals, 0.0)
    # pass 1: sort (the mssortk systolic sort pass)
    keys, vals = net.bitonic_sort(keys, vals)
    # combine duplicates (the paper's C-state PEs)
    keys, vals = net.combine_duplicates(keys, vals)
    # pass 2: compress (valid tuples to the front, MXU routing)
    keys, vals, n = net.compress_onehot(keys, vals)
    ok_ref[...] = keys
    ov_ref[...] = vals.astype(ov_ref.dtype)
    ol_ref[...] = n[:, None]


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def stream_sort_pallas(keys, vals, lens, *, block_s: int = 8,
                       interpret: bool = True):
    """keys: (S, R) int32; vals: (S, R) float; lens: (S,) int32.
    Returns (out_keys, out_vals, out_lens). R must be a power of two."""
    S, R = keys.shape
    assert R & (R - 1) == 0, "R must be a power of two"
    block_s = min(block_s, S)
    pad = (-S) % block_s
    if pad:
        keys = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=EMPTY)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        lens = jnp.pad(lens, (0, pad))
    Sp = S + pad
    lens2 = lens[:, None].astype(jnp.int32)
    grid = (Sp // block_s,)
    kv_spec = pl.BlockSpec((block_s, R), lambda i: (i, 0))
    len_spec = pl.BlockSpec((block_s, 1), lambda i: (i, 0))
    ok, ov, ol = pl.pallas_call(
        _stream_sort_kernel,
        grid=grid,
        in_specs=[kv_spec, kv_spec, len_spec],
        out_specs=[kv_spec, kv_spec, len_spec],
        out_shape=[
            jax.ShapeDtypeStruct((Sp, R), jnp.int32),
            jax.ShapeDtypeStruct((Sp, R), vals.dtype),
            jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(keys, vals, lens2)
    return ok[:S], ov[:S], ol[:S, 0]
