"""Public jit'd wrappers over the zipper kernels, routed through the
kernel-backend registry (``kernels/backend.py``).

``backend`` everywhere below is a registered backend name (``"xla"``,
``"pallas"``, ``"ref"``), ``"auto"`` (pallas on TPU, xla elsewhere), or a
resolved :class:`~repro.kernels.backend.KernelBackend` instance.  Unknown
names raise ``ValueError`` listing the registered backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import EMPTY
from repro.kernels import backend as kb


def _pad_streams(cap_s, keys, vals, lens):
    """Pad the stream axis up to a fixed capacity ``cap_s``.

    Batched drivers issue many chunk kernels whose stream count S varies
    (ragged tail groups, per-chunk participation); padding every issue to
    one static (cap_s, R) shape keeps a single XLA/Pallas compilation live
    across the whole batch instead of one per distinct S."""
    S = keys.shape[0]
    if cap_s is None or cap_s <= S:
        return keys, vals, lens, S
    pad = cap_s - S
    keys = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=EMPTY)
    vals = jnp.pad(vals, ((0, pad), (0, 0)))
    lens = jnp.pad(lens, (0, pad))
    return keys, vals, lens, S


def stream_sort(keys, vals, lens, *, backend="auto", cap_s=None):
    """mssortk+mssortv: sort/combine/compress S key-value chunks.

    ``cap_s``: optional static stream-count capacity; inputs with S < cap_s
    are padded up so every call shares one compiled kernel."""
    keys, vals, lens, S = _pad_streams(cap_s, keys, vals, lens)
    bk = kb.resolve_backend(backend)
    ok, ov, ol = bk.stream_sort(keys, vals, lens)
    return ok[:S], ov[:S], ol[:S]


def stream_merge(ka, va, la, kb_, vb, lb, *, backend="auto", cap_s=None):
    """mszipk+mszipv: merge two sorted chunks per stream.

    ``cap_s``: as in :func:`stream_sort` — static stream-count capacity."""
    ka, va, la, S = _pad_streams(cap_s, ka, va, la)
    kb_, vb, lb, _ = _pad_streams(cap_s, kb_, vb, lb)
    bk = kb.resolve_backend(backend)
    outs = bk.stream_merge(ka, va, la, kb_, vb, lb)
    return tuple(o[:S] for o in outs)


@functools.partial(jax.jit, static_argnames=("R", "pair_streams",
                                             "with_counters", "backend"))
def _merge_partitions_jit(ka, va, la, kb_, vb, lb, *, R, pair_streams,
                          with_counters, backend):
    return kb.get_backend(backend).merge_partitions(
        ka, va, la, kb_, vb, lb, R=R, pair_streams=pair_streams,
        with_counters=with_counters)


def merge_partitions(ka, va, la, kb_, vb, lb, *, R: int = 16,
                     pair_streams: int | None = None,
                     with_counters: bool = True, backend="auto"):
    """Device-resident partition merge: the full data-dependent chunk
    advancement of two padded (N, L) sorted-unique partitions, with the
    pointer state machine under ``jax.lax.while_loop`` (see
    kernels/merge_tree.py).

    Returns (keys (N, La+Lb), vals, lens, MergeCounters)."""
    return _merge_partitions_jit(
        jnp.asarray(ka), jnp.asarray(va), jnp.asarray(la),
        jnp.asarray(kb_), jnp.asarray(vb), jnp.asarray(lb),
        R=R, pair_streams=pair_streams, with_counters=with_counters,
        backend=kb.resolve_backend(backend).name)


def sort_tokens_by_key(keys, *, backend="auto"):
    """Zipper-dispatch helper used by the MoE layer: ascending argsort of a
    1-D key vector, implemented as a stream sort whose values are slot ids.

    Unlike stream_sort, duplicates are kept (each key is made unique by
    packing the slot id into the low bits), because MoE dispatch must not
    merge tokens routed to the same expert — it only needs them grouped.
    Returns (sorted_keys, perm) such that keys[perm] == sorted_keys.
    """
    (n,) = keys.shape
    bits = max(1, (n - 1).bit_length())
    slot = jnp.arange(n, dtype=jnp.int32)
    packed = (keys.astype(jnp.int32) << bits) | slot
    bk = kb.resolve_backend(backend)
    if bk.name == "pallas" and n & (n - 1) == 0 and n >= 8:
        vals = slot.astype(jnp.float32)
        pk, pv, _ = bk.stream_sort(packed[None, :], vals[None, :],
                                   jnp.array([n], jnp.int32))
        perm = pv[0].astype(jnp.int32)
        return pk[0] >> bits, perm
    order = jnp.argsort(packed)
    return keys[order], order.astype(jnp.int32)
