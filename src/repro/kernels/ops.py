"""Public jit'd wrappers over the Pallas kernels with impl selection.

``impl``:
  "pallas" — pl.pallas_call (interpret=True automatically off-TPU)
  "xla"    — the pure-jnp oracle (ref.py), used for GSPMD dry-runs where
             the model graph must lower for a 512-device CPU mesh
  "auto"   — pallas on TPU, xla elsewhere (kernels are still exercised in
             interpret mode by the test/benchmark suites)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.formats import EMPTY
from repro.kernels import merge_tree, ref
from repro.kernels.stream_sort import stream_sort_pallas
from repro.kernels.stream_merge import stream_merge_pallas

# jitted oracles: the xla impl is used as a driver workhorse (SpGEMM chunk
# loops), where eager dispatch of the vmap/segment_sum graph would dominate
_sort_ref = jax.jit(ref.stream_sort_ref)
_merge_ref = jax.jit(ref.stream_merge_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "xla"
    return impl


def _pad_streams(cap_s, keys, vals, lens):
    """Pad the stream axis up to a fixed capacity ``cap_s``.

    Batched drivers issue many chunk kernels whose stream count S varies
    (ragged tail groups, per-chunk participation); padding every issue to
    one static (cap_s, R) shape keeps a single XLA/Pallas compilation live
    across the whole batch instead of one per distinct S."""
    S = keys.shape[0]
    if cap_s is None or cap_s <= S:
        return keys, vals, lens, S
    pad = cap_s - S
    keys = jnp.pad(keys, ((0, pad), (0, 0)), constant_values=EMPTY)
    vals = jnp.pad(vals, ((0, pad), (0, 0)))
    lens = jnp.pad(lens, (0, pad))
    return keys, vals, lens, S


def stream_sort(keys, vals, lens, *, impl: str = "auto", block_s: int = 8,
                cap_s: int | None = None):
    """mssortk+mssortv: sort/combine/compress S key-value chunks.

    ``cap_s``: optional static stream-count capacity; inputs with S < cap_s
    are padded up so every call shares one compiled kernel."""
    keys, vals, lens, S = _pad_streams(cap_s, keys, vals, lens)
    impl = _resolve(impl)
    if impl == "pallas":
        ok, ov, ol = stream_sort_pallas(keys, vals, lens, block_s=block_s,
                                        interpret=not _on_tpu())
    else:
        ok, ov, ol = _sort_ref(keys, vals, lens)
    return ok[:S], ov[:S], ol[:S]


def stream_merge(ka, va, la, kb, vb, lb, *, impl: str = "auto",
                 block_s: int = 8, cap_s: int | None = None):
    """mszipk+mszipv: merge two sorted chunks per stream.

    ``cap_s``: as in :func:`stream_sort` — static stream-count capacity."""
    ka, va, la, S = _pad_streams(cap_s, ka, va, la)
    kb, vb, lb, _ = _pad_streams(cap_s, kb, vb, lb)
    impl = _resolve(impl)
    if impl == "pallas":
        outs = stream_merge_pallas(ka, va, la, kb, vb, lb, block_s=block_s,
                                   interpret=not _on_tpu())
    else:
        outs = _merge_ref(ka, va, la, kb, vb, lb)
    return tuple(o[:S] for o in outs)


def _sort_chunk_fn(impl: str):
    """The (S, R) chunk-sort kernel a device-resident pipeline should issue.

    The xla path uses the scatter-free ``sort_chunks_linear`` — byte-
    identical to ``ref.stream_sort_ref`` (same stable order, same linear
    accumulation) but much cheaper inside a fused computation."""
    if _resolve(impl) == "pallas":
        return functools.partial(stream_sort_pallas, interpret=not _on_tpu())
    return merge_tree.sort_chunks_linear


@functools.partial(jax.jit,
                   static_argnames=("R", "pair_streams", "with_counters"))
def merge_partitions(ka, va, la, kb, vb, lb, *, R: int = 16,
                     pair_streams: int | None = None,
                     with_counters: bool = True):
    """Device-resident partition merge: the full data-dependent chunk
    advancement of two padded (N, L) sorted-unique partitions, with the
    pointer state machine under ``jax.lax.while_loop`` (see
    kernels/merge_tree.py).

    Returns (keys (N, La+Lb), vals, lens, MergeCounters)."""
    return merge_tree.merge_partitions(
        jnp.asarray(ka), jnp.asarray(va), jnp.asarray(la),
        jnp.asarray(kb), jnp.asarray(vb), jnp.asarray(lb),
        R=R, pair_streams=pair_streams, with_counters=with_counters)


def sort_tokens_by_key(keys, *, impl: str = "auto"):
    """Zipper-dispatch helper used by the MoE layer: ascending argsort of a
    1-D key vector, implemented as a stream sort whose values are slot ids.

    Unlike stream_sort, duplicates are kept (each key is made unique by
    packing the slot id into the low bits), because MoE dispatch must not
    merge tokens routed to the same expert — it only needs them grouped.
    Returns (sorted_keys, perm) such that keys[perm] == sorted_keys.
    """
    (n,) = keys.shape
    bits = max(1, (n - 1).bit_length())
    slot = jnp.arange(n, dtype=jnp.int32)
    packed = (keys.astype(jnp.int32) << bits) | slot
    impl = _resolve(impl)
    if impl == "pallas" and n & (n - 1) == 0 and n >= 8:
        vals = slot.astype(jnp.float32)
        pk, pv, _ = stream_sort_pallas(packed[None, :], vals[None, :],
                                       jnp.array([n], jnp.int32),
                                       interpret=not _on_tpu())
        perm = pv[0].astype(jnp.int32)
        return pk[0] >> bits, perm
    order = jnp.argsort(packed)
    return keys[order], order.astype(jnp.int32)
