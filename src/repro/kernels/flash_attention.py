"""Pallas TPU kernel: blocked causal/windowed GQA flash attention.

The training/prefill hot spot. Grid (batch*heads, q_blocks, kv_blocks) with
kv innermost; online-softmax running state (m, l, acc) lives in VMEM
scratch across kv steps; causal/window block skipping is done with
pl.when so skipped tiles cost control flow only. Oracle: ref.mha_ref.

Layout: q is reshaped to (B*H, Sq, hd) and k/v to (B*KVH, Skv, hd) by the
wrapper; the k/v BlockSpec index map folds the GQA head mapping
(kv row = batch*KVH + q_head // group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale, causal, window, bq, bk, nk, sq, skv):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_end = iq * bq + bq - 1 + (skv - sq)   # global pos of last q in block
    k_start = ik * bk
    run_pred = (k_start <= q_end) if causal else (ik >= 0)

    @pl.when(run_pred)
    def _body():
        q = q_ref[0].astype(jnp.float32)        # (bq, hd)
        k = k_ref[0].astype(jnp.float32)        # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
            + (skv - sq)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret", "scale"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0, bq=128,
                           bk=128, scale=None, interpret=True):
    """q: (B, Sq, H, hd); k/v: (B, Skv, KVH, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale if scale is not None else hd ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sqp, Skp = Sq + pad_q, Skv + pad_k
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sqp, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KVH, Skp, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KVH, Skp, hd)
    nq, nk = Sqp // bq, Skp // bk

    def kv_row(bh):
        return (bh // H) * KVH + (bh % H) // G

    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          window=window, bq=bq, bk=bk, nk=nk, sq=Sq,
                          skv=Skv),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, iq, ik: (kv_row(bh), ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, H, Sqp, hd).transpose(0, 2, 1, 3)
    return out[:, :Sq]
