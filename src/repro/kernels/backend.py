"""Kernel-backend registry: one interface over the XLA / Pallas / reference
implementations of the zipper stream primitives.

SparseZipper's pitch is that one micro-architectural substrate (the
systolic array) serves both dense GEMM and the stream sort/merge
primitives.  This reproduction's analogue of "substrate" is the kernel
implementation tier, and this module makes it a first-class, planned
dimension instead of an ``impl=`` string threaded through every call
site: a :class:`KernelBackend` bundles the four stream primitives —

  ``chunk_sort``        (N, R) chunk sort/combine/compress, traceable
                        inside the fused pipeline's jitted buckets
  ``stream_sort``       host-tier mssortk+mssortv kernel issue
  ``stream_merge``      host-tier mszipk+mszipv kernel issue
  ``merge_partitions``  device-resident full partition merge (the
                        zip-merge tree's primitive)

— plus the optional whole-pipeline slot

  ``fused_bucket``      sort + the entire zip-merge tree for one
                        (S, L, R) work bucket as ONE kernel issue
                        (``None``: the driver composes chunk_sort +
                        the XLA merge tree instead)

— plus declared capabilities, and the registry resolves a backend ONCE
(at plan time, in ``core/dispatch.py``) rather than per kernel issue.
Registered instances:

  ``xla``     pure-jnp oracles jitted as XLA computations (the driver
              workhorse off-TPU)
  ``pallas``  ``pl.pallas_call`` kernels (interpret mode automatically
              off-TPU): the native chunk-sort, the native
              ``merge_partitions`` bitonic-merge kernel, and the
              single-kernel fused bucket pipeline (chunks stay in VMEM
              across merge rounds) — all bit-identical to ``xla``
  ``ref``     the unjitted pure-jnp oracles (eager; debugging)

Every backend here is bit-compatible: same keys, values, lengths, and
instruction counters on the same inputs, so engine selection is purely a
performance decision.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Union

import jax

from repro.kernels import merge_tree, ref
from repro.kernels.chunk_sort import chunk_sort_pallas
from repro.kernels.fused_bucket import fused_bucket_pallas
from repro.kernels.merge_partitions import merge_partitions_pallas
from repro.kernels.stream_merge import stream_merge_pallas
from repro.kernels.stream_sort import stream_sort_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """A registered kernel implementation tier and its capabilities.

    ``on_device``: kernels lower into jitted device computations (False
    for the eager reference oracles).  ``counters_exact``: instruction
    counters derived from this backend's kernels match the host driver's
    per-issue accounting exactly (a future approximate TPU merge kernel
    would declare False and be skipped where exact Fig. 10/11 stats are
    required).  ``measure``: candidate for autotune measurement.
    ``needs_tpu_for_perf``: off-TPU this backend runs in a degraded mode
    (Pallas interpret) where timing it is meaningless — autotune sweeps
    include it on real TPU hardware only, and a cached plan recorded on
    a TPU host falls back to "auto" when replayed elsewhere."""

    name: str
    chunk_sort: Callable
    stream_sort: Callable
    stream_merge: Callable
    merge_partitions: Callable
    fused_bucket: Optional[Callable] = None
    on_device: bool = True
    counters_exact: bool = True
    measure: bool = True
    needs_tpu_for_perf: bool = False
    description: str = ""


_BACKENDS: dict[str, KernelBackend] = {}


def register_backend(**fields) -> KernelBackend:
    """Register (or replace) a backend; see :class:`KernelBackend`."""
    bk = KernelBackend(**fields)
    _BACKENDS[bk.name] = bk
    return bk


def get_backend(name: str) -> KernelBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown kernel backend {name!r}; registered: "
            f"{sorted(_BACKENDS)} (or 'auto')") from None


def resolve_backend(backend: Union[str, KernelBackend] = "auto",
                    ) -> KernelBackend:
    """Resolve a backend request — a registered name, "auto" (pallas on
    TPU, xla elsewhere), or an already-resolved instance — to the
    :class:`KernelBackend`.  Unknown names raise ``ValueError`` listing
    the registered backends."""
    if isinstance(backend, KernelBackend):
        return backend
    if backend == "auto":
        return _BACKENDS["pallas" if on_tpu() else "xla"]
    return get_backend(backend)


def available_backends() -> dict[str, KernelBackend]:
    """Snapshot of the registry (name -> backend)."""
    return dict(_BACKENDS)


def measurable_backends() -> list[KernelBackend]:
    """Backends worth timing on THIS host — the autotune sweep space.
    Filters ``measure=False`` tiers and, off-TPU, tiers that would be
    measured in a degraded mode (``needs_tpu_for_perf``)."""
    return [bk for bk in _BACKENDS.values()
            if bk.measure and (on_tpu() or not bk.needs_tpu_for_perf)]


# jitted oracles: the xla tier is the driver workhorse off-TPU (SpGEMM
# chunk loops), where eager dispatch of the vmap/segment_sum graph would
# dominate
_sort_ref = jax.jit(ref.stream_sort_ref)
_merge_ref = jax.jit(ref.stream_merge_ref)


def _pallas_chunk_sort(keys, vals, lens):
    return chunk_sort_pallas(keys, vals, lens, interpret=not on_tpu())


def _pallas_stream_sort(keys, vals, lens):
    return stream_sort_pallas(keys, vals, lens, interpret=not on_tpu())


def _pallas_stream_merge(ka, va, la, kb, vb, lb):
    return stream_merge_pallas(ka, va, la, kb, vb, lb,
                               interpret=not on_tpu())


def _pallas_merge_partitions(ka, va, la, kb, vb, lb, *, R,
                             pair_streams=None, with_counters=True):
    return merge_partitions_pallas(ka, va, la, kb, vb, lb, R=R,
                                   pair_streams=pair_streams,
                                   with_counters=with_counters,
                                   interpret=not on_tpu())


def _pallas_fused_bucket(keys, vals, plens, *, R, with_counters=True,
                         detailed=False):
    return fused_bucket_pallas(keys, vals, plens, R=R,
                               with_counters=with_counters,
                               detailed=detailed, interpret=not on_tpu())


register_backend(
    name="xla",
    chunk_sort=merge_tree.sort_chunks_linear,
    stream_sort=_sort_ref,
    stream_merge=_merge_ref,
    merge_partitions=merge_tree.merge_partitions,
    description="pure-jnp oracles jitted as XLA computations; the "
                "scatter-free sort_chunks_linear is the fused sort stage")
register_backend(
    name="pallas",
    chunk_sort=_pallas_chunk_sort,
    stream_sort=_pallas_stream_sort,
    stream_merge=_pallas_stream_merge,
    merge_partitions=_pallas_merge_partitions,
    fused_bucket=_pallas_fused_bucket,
    needs_tpu_for_perf=True,
    description="pl.pallas_call kernels (interpret mode off-TPU); the "
                "native chunk-sort, bitonic merge_partitions, and the "
                "single-kernel fused bucket pipeline (VMEM-resident "
                "merge tree)")
register_backend(
    name="ref",
    chunk_sort=ref.stream_sort_ref,
    stream_sort=ref.stream_sort_ref,
    stream_merge=ref.stream_merge_ref,
    merge_partitions=merge_tree.merge_partitions,
    on_device=False,
    measure=False,
    description="unjitted pure-jnp oracles (eager; debugging tier)")
