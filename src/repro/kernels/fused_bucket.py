"""Pallas TPU kernel: sort + the whole zip-merge tree in ONE pallas_call.

The fused spz driver processes one (S, L, R) work bucket as "chunk-sort
everything, then log2(C) merge rounds".  With the stage kernels issued
separately, every round's partition buffers round-trip through HBM — the
exact spill SparseZipper (and SpArch's hierarchical merge tree) exist to
avoid.  This kernel runs the entire bucket pipeline inside a single
``pallas_call``: one program holds its (BLOCK_S, L) stream tile in VMEM,
chunk-sorts all BLOCK_S*C R-chunks (``chunk_sort.sort_tile`` — the same
tile the standalone chunk-sort kernel runs), then folds the C sorted
partitions through log2(C) unrolled rounds of
``merge_partitions.merge_tile`` without the intermediate partitions ever
leaving VMEM.

Counters: the lock-step instruction accounting must match the host
driver per *group*, but one program only sees its own streams — so each
round also runs the per-stream ``advance_tile`` state machine and the
kernel emits per-(stream, round-pair) step/zip/tail counts.  The wrapper
reduces them across the full stream axis exactly the way
``merge_tree.zip_merge_tree(detailed=True)`` reports rounds (a pair's
issue count is the max over its streams, zip_elems a sum, tails the max
of per-side ceil(rem/R)), so ``spgemm.fused_process_group`` consumes the
result unchanged and rebuilds group-exact ``n_mssort``/``n_mszip``.

Invariants: R is a power of two (bitonic sort width) and C = L/R is a
power of two (balanced merge tree); keys beyond ``plens`` are EMPTY
(they are masked again chunk-wise before sorting); valid keys < 2**31-1.
Counter layout in the kernel outputs: round r's pairs occupy columns
[C - C>>r, C - C>>(r+1)) of the (S, C-1) per-stream counter planes —
round 0 first, C/2 + C/4 + ... + 1 = C-1 columns total.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import EMPTY
from repro.kernels.chunk_sort import sort_tile
from repro.kernels.merge_partitions import advance_tile, merge_tile


def _fused_bucket_kernel(keys_ref, vals_ref, plens_ref,
                         ok_ref, ov_ref, ol_ref,
                         st_ref, zp_ref, ta_ref, tb_ref, *,
                         R: int, C: int, with_counters: bool):
    Sb, L = keys_ref.shape
    keys = keys_ref[...]
    vals = vals_ref[...].astype(jnp.float32)
    plens = plens_ref[...]  # (Sb, 1)
    # per-chunk valid counts: chunk c of a stream holds
    # clip(plen - c*R, 0, R) products
    coff = jnp.arange(C, dtype=jnp.int32)[None, :] * R
    clens = jnp.clip(plens - coff, 0, R).reshape(Sb * C, 1)
    # sort stage: identical tile to the standalone chunk-sort kernel
    pk, pv, pn = sort_tile(keys.reshape(Sb * C, R),
                           vals.reshape(Sb * C, R), clens)
    cnt_cols = [[], [], [], []]  # per-round (Sb, half) planes, in order
    # merge tree: fold pairs of sorted partitions, chunks never leave VMEM
    cur_c, W = C, R
    while cur_c > 1:
        half = cur_c // 2
        k3 = pk.reshape(Sb, cur_c, W)
        v3 = pv.reshape(Sb, cur_c, W)
        n3 = pn.reshape(Sb, cur_c)
        ka = k3[:, 0::2].reshape(Sb * half, W)
        va = v3[:, 0::2].reshape(Sb * half, W)
        la = n3[:, 0::2].reshape(Sb * half, 1)
        kb = k3[:, 1::2].reshape(Sb * half, W)
        vb = v3[:, 1::2].reshape(Sb * half, W)
        lb = n3[:, 1::2].reshape(Sb * half, 1)
        pk, pv, pn = merge_tile(ka, va, la, kb, vb, lb)
        if with_counters:
            round_cnts = advance_tile(ka, la, kb, lb, R)
            for cols, c_r in zip(cnt_cols, round_cnts):
                cols.append(c_r.reshape(Sb, half))
        cur_c, W = half, 2 * W
    ok_ref[...] = pk.reshape(Sb, L)
    ov_ref[...] = pv.reshape(Sb, L).astype(ov_ref.dtype)
    ol_ref[...] = pn.reshape(Sb, 1)
    for ref, cols in zip((st_ref, zp_ref, ta_ref, tb_ref), cnt_cols):
        if cols and sum(c.shape[1] for c in cols) == ref.shape[1]:
            ref[...] = jnp.concatenate(cols, axis=1)
        else:  # C == 1 (no rounds) or counters skipped: zero planes
            ref[...] = jnp.zeros(ref.shape, jnp.int32)


@functools.partial(jax.jit, static_argnames=("R", "with_counters",
                                             "detailed", "block_s",
                                             "interpret"))
def fused_bucket_pallas(keys, vals, plens, *, R: int,
                        with_counters: bool = True, detailed: bool = False,
                        block_s: int = 8, interpret: bool = True):
    """Sort + full zip-merge tree over one (S, L, R) work bucket in one
    kernel issue — same contract as ``core/stream.fused_sort_merge``.

    keys/vals: (S, L) unsorted padded product streams, L = C*R with both
    R and C powers of two; plens: (S,) valid lengths.  Returns
    (keys (S, L), vals, lens (S,), counters (6,)) with the host driver's
    [n_mssort, sort_elems, n_mszip, zip_elems, chunk_loads, chunk_stores]
    accounting, or — with ``detailed=True`` — the per-(round, pair)
    counter tuples in ``merge_tree.zip_merge_tree(detailed=True)`` form.
    Bit-identical to the XLA sort + merge-tree composition.
    """
    S, L = keys.shape
    C = L // R
    assert C * R == L, f"partition width {L} must be a multiple of R={R}"
    assert R & (R - 1) == 0, "R must be a power of two"
    assert C & (C - 1) == 0, f"partition count {C} must be a power of two"
    plens = plens.astype(jnp.int32)
    n_mssort = (-(-jnp.max(plens) // R)).astype(jnp.int32)
    sort_elems = jnp.sum(plens, dtype=jnp.int32)
    # counter planes: round r at columns [C - (C >> r), ...), C-1 total
    Cm1 = max(C - 1, 1)
    block_s = min(block_s if not interpret else S, S)
    pad_s = (-S) % block_s
    if pad_s:
        keys = jnp.pad(keys, ((0, pad_s), (0, 0)), constant_values=EMPTY)
        vals = jnp.pad(vals, ((0, pad_s), (0, 0)))
        plens = jnp.pad(plens, (0, pad_s))
    Sp = S + pad_s
    grid = (Sp // block_s,)
    row_spec = pl.BlockSpec((block_s, L), lambda i: (i, 0))
    one_spec = pl.BlockSpec((block_s, 1), lambda i: (i, 0))
    cnt_spec = pl.BlockSpec((block_s, Cm1), lambda i: (i, 0))
    kernel = functools.partial(_fused_bucket_kernel, R=R, C=C,
                               with_counters=with_counters or detailed)
    ok, ov, ol, st, zp, ta, tb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[row_spec, row_spec, one_spec],
        out_specs=[row_spec, row_spec, one_spec] + [cnt_spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((Sp, L), jnp.int32),
            jax.ShapeDtypeStruct((Sp, L), vals.dtype),
            jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
        ] + [jax.ShapeDtypeStruct((Sp, Cm1), jnp.int32)] * 4,
        interpret=interpret,
    )(keys, vals, plens[:, None])
    mk, mv, ml = ok[:S], ov[:S], ol[:S, 0]
    # padded streams contribute zero steps/zips/tails, so reducing over
    # the padded axis is safe; reduce exactly like zip_merge_tree reports
    rounds = []
    col, half = 0, C // 2
    while half >= 1:
        steps = jnp.max(st[:, col:col + half], axis=0)
        ze = jnp.sum(zp[:, col:col + half], dtype=jnp.int32)
        tails = jnp.stack([jnp.max(ta[:, col:col + half], axis=0),
                           jnp.max(tb[:, col:col + half], axis=0)], axis=1)
        rounds.append((steps, ze, tails))
        col, half = col + half, half // 2
    if detailed:
        return mk, mv, ml, tuple(rounds)
    if with_counters:
        n_zip = sum((jnp.sum(r[0], dtype=jnp.int32) for r in rounds),
                    jnp.zeros((), jnp.int32))
        zip_elems = sum((r[1] for r in rounds), jnp.zeros((), jnp.int32))
        tail_sum = sum((jnp.sum(r[2], dtype=jnp.int32) for r in rounds),
                       jnp.zeros((), jnp.int32))
        chunk_loads = 2 * n_zip
        chunk_stores = n_zip + tail_sum
    else:
        n_zip = zip_elems = chunk_loads = chunk_stores = \
            jnp.zeros((), jnp.int32)
    counters = jnp.stack([n_mssort, sort_elems, n_zip, zip_elems,
                          n_mssort + chunk_loads, n_mssort + chunk_stores])
    return mk, mv, ml, counters
