"""Pure-jnp oracles for every Pallas kernel in this package.

Semantics mirror the SparseZipper ISA (paper §III):

``stream_sort_ref``  == mssortk.tt + mssortv.tt
    Sort each stream's key chunk ascending, accumulate values of duplicate
    keys, compress valid tuples to the front. Returns output lengths
    (the OC counter registers).

``stream_merge_ref`` == mszipk.tt + mszipv.tt
    Two-way merge of two *sorted, duplicate-free* chunks per stream.
    Keys greater than every key on the other side are "unmergeable"
    (paper: merge bit never set) and are NOT emitted; the per-side consumed
    counts (the IC counter registers) tell the driver how far each input
    partition advanced. Output is a sorted duplicate-accumulated chunk of
    up to 2R tuples, split into a low half and a high half (paper: east- and
    south-side outputs).

Keys are int32 in [0, 2**31-2]; EMPTY = INT32_MAX is the invalid sentinel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import EMPTY


def _mask_chunk(keys, vals, lens):
    """Invalidate positions >= lens (per stream)."""
    r = jnp.arange(keys.shape[-1], dtype=jnp.int32)
    valid = r[None, :] < lens[:, None]
    return jnp.where(valid, keys, EMPTY), jnp.where(valid, vals, 0)


def _sort_combine_compress(keys, vals):
    """Shared tail: sort by key, accumulate duplicate keys, compress.

    keys: (S, W) int32 (EMPTY = invalid), vals: (S, W) float.
    Returns (keys, vals, out_lens) with uniques packed at the front.
    """
    order = jnp.argsort(keys, axis=-1)
    k = jnp.take_along_axis(keys, order, axis=-1)
    v = jnp.take_along_axis(vals, order, axis=-1)
    # accumulate duplicates onto the LAST element of each equal-key run
    prev = jnp.concatenate([jnp.full_like(k[:, :1], EMPTY), k[:, :-1]], axis=-1)
    nxt = jnp.concatenate([k[:, 1:], jnp.full_like(k[:, :1], EMPTY)], axis=-1)
    seg_start = (k != prev).astype(jnp.int32)
    seg_id = jnp.cumsum(seg_start, axis=-1) - 1
    acc = jax.vmap(
        lambda vv, ss: jax.ops.segment_sum(vv, ss, num_segments=k.shape[-1])
    )(v, seg_id)
    run_total = jnp.take_along_axis(acc, seg_id, axis=-1)
    is_last = (k != nxt) & (k != EMPTY)
    k2 = jnp.where(is_last, k, EMPTY)
    v2 = jnp.where(is_last, run_total, 0)
    # compress: stable re-sort sends EMPTY to the back, keeps uniques ordered
    order2 = jnp.argsort(k2, axis=-1, stable=True)
    k3 = jnp.take_along_axis(k2, order2, axis=-1)
    v3 = jnp.take_along_axis(v2, order2, axis=-1)
    out_lens = jnp.sum(k3 != EMPTY, axis=-1, dtype=jnp.int32)
    return k3, v3.astype(vals.dtype), out_lens


def stream_sort_ref(keys, vals, lens):
    """Sort + combine + compress key-value chunks across S streams.

    keys: (S, R) int32, vals: (S, R) float, lens: (S,) int32.
    Returns (out_keys (S,R), out_vals (S,R), out_lens (S,)).
    """
    k, v = _mask_chunk(keys, vals, lens)
    return _sort_combine_compress(k, v)


def stream_merge_ref(ka, va, la, kb, vb, lb):
    """Merge two sorted duplicate-free chunks per stream.

    Returns (k_lo, v_lo, k_hi, v_hi, consumed_a, consumed_b, out_lens)
    where (k_lo|k_hi) is the packed sorted merged output of length
    out_lens <= 2R, consumed_* are per-side advanced counts.
    """
    R = ka.shape[-1]
    ka_m, va_m = _mask_chunk(ka, va, la)
    kb_m, vb_m = _mask_chunk(kb, vb, lb)
    # max valid key per side; -1 when the side is empty
    max_a = jnp.max(jnp.where(ka_m != EMPTY, ka_m, -1), axis=-1)
    max_b = jnp.max(jnp.where(kb_m != EMPTY, kb_m, -1), axis=-1)
    cutoff = jnp.minimum(max_a, max_b)  # unmergeable beyond this
    merge_a = (ka_m != EMPTY) & (ka_m <= cutoff[:, None])
    merge_b = (kb_m != EMPTY) & (kb_m <= cutoff[:, None])
    consumed_a = jnp.sum(merge_a, axis=-1, dtype=jnp.int32)
    consumed_b = jnp.sum(merge_b, axis=-1, dtype=jnp.int32)
    cat_k = jnp.concatenate(
        [jnp.where(merge_a, ka_m, EMPTY), jnp.where(merge_b, kb_m, EMPTY)], axis=-1)
    cat_v = jnp.concatenate(
        [jnp.where(merge_a, va_m, 0), jnp.where(merge_b, vb_m, 0)], axis=-1)
    k, v, out_lens = _sort_combine_compress(cat_k, cat_v)
    return k[:, :R], v[:, :R], k[:, R:], v[:, R:], consumed_a, consumed_b, out_lens


# ---------------------------------------------------------------------------
# flash attention oracle (used by kernels/flash_attention.py tests)
# ---------------------------------------------------------------------------

def mha_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q: (B, Sq, H, D); k/v: (B, Sk, KVH, D). GQA by head broadcast."""
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    rep = H // KVH
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    Sk = k.shape[1]
    qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
    ki = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qi >= ki
    if window:
        mask &= qi - ki < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# grouped (per-expert) matmul oracle
# ---------------------------------------------------------------------------

def grouped_matmul_ref(x, w, group_sizes):
    """x: (T, D) rows grouped by expert (group g owns rows
    [cum[g], cum[g]+group_sizes[g])); w: (E, D, F). Rows beyond the last
    group are zeroed. Returns (T, F)."""
    T = x.shape[0]
    E = w.shape[0]
    cum = jnp.cumsum(group_sizes)
    starts = cum - group_sizes
    row = jnp.arange(T)
    gid = jnp.searchsorted(cum, row, side="right").clip(0, E - 1)
    valid = row < cum[-1]
    wg = w[gid]
    out = jnp.einsum("td,tdf->tf", x, wg)
    return jnp.where(valid[:, None], out, 0)
