"""Checkpointing: atomic, async-capable, keep-k, reshard-on-restore.

Layout: <dir>/step_<n>/ arrays.npz + tree.json, committed by atomically
renaming a .tmp directory (a torn write can never be mistaken for a
complete checkpoint). ``restore`` rebuilds arrays with whatever shardings
the *restoring* process supplies — this is the elastic-scaling path: save
on one mesh, restore on another.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree):
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         blocking: bool = True) -> threading.Thread | None:
    """Save a pytree. blocking=False returns the committing thread (async
    save: device->host copy happens before returning; disk IO overlaps)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    # device -> host before going async (so training can mutate buffers)
    host_leaves = [np.asarray(l) for l in leaves]
    dtypes = [str(l.dtype) for l in host_leaves]
    # npz cannot represent ml_dtypes (bfloat16 -> void): store a u16 view
    host_leaves = [
        l.view(np.uint16) if l.dtype.str == "<V2" or "bfloat16" in str(l.dtype)
        else l
        for l in host_leaves]
    paths = _paths(tree)

    def commit():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": l for i, l in enumerate(host_leaves)})
        meta = {"step": step, "paths": paths, "dtypes": dtypes}
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if blocking:
        commit()
        return None
    t = threading.Thread(target=commit, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    return [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
            if d.startswith("step_")]


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``target_tree`` (shapes/dtypes source
    of truth is the checkpoint). ``shardings``: optional pytree of
    NamedShardings — arrays are placed with them (reshard-on-restore;
    the saving mesh is irrelevant)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        host = [z[f"a{i}"] for i in range(len(z.files))]
    with open(os.path.join(d, "tree.json")) as f:
        meta = json.load(f)
    import ml_dtypes
    host = [h.view(ml_dtypes.bfloat16) if dt == "bfloat16" else h
            for h, dt in zip(host, meta["dtypes"])]
    leaves, treedef = _flatten(target_tree)
    assert len(leaves) == len(host), \
        f"checkpoint has {len(host)} leaves, target {len(leaves)}"
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        out = [jax.device_put(h, s) for h, s in zip(host, shard_leaves)]
    else:
        out = [jax.numpy.asarray(h) for h in host]
    return jax.tree_util.tree_unflatten(treedef, out)
