"""AdamW with dtype-configurable moments (bf16 moments let arctic-480b fit
16 GB/chip), decoupled weight decay, global-norm clipping, and gradient
accumulation. No external deps (optax is not assumed)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"
    # schedule
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # distributed tricks
    grad_accum: int = 1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.decay_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_state(cfg: AdamWConfig, params):
    dt = jnp.dtype(cfg.state_dtype)
    def zeros(p):
        return jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


_NO_DECAY = ("norm", "scale", "bias", "a_param", "dt_bias", "d_skip")


def _decay_mask(path) -> bool:
    s = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in path)
    return not any(t in s for t in _NO_DECAY)


def apply_updates(cfg: AdamWConfig, params, opt_state, grads):
    """One AdamW step. Returns (params, opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(path, p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        u = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if _decay_mask(path):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p2, m32.astype(sdt), v32.astype(sdt)

    flat = jax.tree_util.tree_map_with_path(
        upd, params, grads, opt_state["m"], opt_state["v"],
        is_leaf=lambda x: isinstance(x, jax.Array))
    new_p = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
