"""State-space blocks: Mamba-2 SSD (chunked state-space duality) and
RG-LRU (RecurrentGemma/Griffin). Both provide full-sequence (train/prefill)
and single-step (decode) forms; sub-quadratic in sequence length, so these
are the archs that run the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init

# ---------------------------------------------------------------------------
# causal depthwise conv1d (width w): shared by SSD and RG-LRU branches
# ---------------------------------------------------------------------------

def conv1d_init(key, width, channels, dtype):
    return {"w": (jax.random.normal(key, (width, channels), jnp.float32)
                  * width ** -0.5).astype(dtype)}


def conv1d(p, x):
    """x: (B, S, C) causal depthwise."""
    w = p["w"].astype(x.dtype)
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[-1 - i]
    return out


def conv1d_step(p, x_t, conv_cache):
    """x_t: (B, 1, C); conv_cache: (B, width-1, C) past inputs.
    Returns (y_t, new_cache)."""
    w = p["w"].astype(x_t.dtype)
    width = w.shape[0]
    window = jnp.concatenate([conv_cache, x_t], axis=1)  # (B, width, C)
    y = jnp.einsum("bwc,wc->bc", window, w)[:, None]
    return y, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

def ssd_init(key, cfg, dtype):
    D = cfg.d_model
    inner = cfg.ssm_expand * D
    H = inner // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 5)
    conv_ch = inner + 2 * N
    return {
        "in_proj": dense_init(ks[0], D, 2 * inner + 2 * N + H, dtype),
        "conv": conv1d_init(ks[1], cfg.conv_width, conv_ch, dtype),
        "a_param": jnp.zeros((H,), jnp.float32),     # A = -exp(a_param)
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_proj": dense_init(ks[2], inner, D, dtype),
        "norm": {"scale": jnp.ones((inner,), dtype)},
    }


def _ssd_split(p, x, cfg):
    D = cfg.d_model
    inner = cfg.ssm_expand * D
    N = cfg.ssm_state
    H = inner // cfg.ssm_head_dim
    zxbcdt = dense(p["in_proj"], x)
    z = zxbcdt[..., :inner]
    xbc = zxbcdt[..., inner:inner + inner + 2 * N]
    dt = zxbcdt[..., -H:]
    return z, xbc, dt, inner, N, H


def ssd_forward(p, x, cfg):
    """Chunked SSD over the full sequence. x: (B, S, D).
    Returns (y, final_state (B,H,P,N), conv_tail (B, cw-1, conv_ch))."""
    B, S, D = x.shape
    z, xbc, dt, inner, N, H = _ssd_split(p, x, cfg)
    cw = cfg.conv_width
    conv_tail = jnp.pad(xbc, ((0, 0), (max(0, cw - 1 - S), 0), (0, 0))
                        )[:, -(cw - 1):]
    xbc = jax.nn.silu(conv1d(p["conv"], xbc))
    P_ = cfg.ssm_head_dim
    xs = xbc[..., :inner].reshape(B, S, H, P_)
    Bm = xbc[..., inner:inner + N]
    Cm = xbc[..., inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_param"])           # (H,) negative
    adt = A * dt                          # (B, S, H) log-decay per step
    dtx = (xs.astype(jnp.float32) * dt[..., None])
    Q = min(cfg.ssm_chunk, S)
    S_orig = S
    pad = (-S) % Q
    if pad:
        # padded steps carry dt=0: a=1 (no decay), dtx=0 (no input) — the
        # final state is exactly the state after step S_orig.
        adt = jnp.pad(adt, ((0, 0), (0, pad), (0, 0)))
        dtx = jnp.pad(dtx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nC = S // Q
    # reshape into chunks
    adt_c = adt.reshape(B, nC, Q, H)
    cum = jnp.cumsum(adt_c, axis=2)       # s_t within chunk
    dtx_c = dtx.reshape(B, nC, Q, H, P_)
    B_c = Bm.reshape(B, nC, Q, N).astype(jnp.float32)
    C_c = Cm.reshape(B, nC, Q, N).astype(jnp.float32)
    # intra-chunk (quadratic within Q): M_ij = C_i.B_j e^{s_i - s_j} [j<=i]
    li = cum[..., :, None, :] - cum[..., None, :, :]       # (B,nC,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, None, ..., None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, dtx_c)
    # chunk-final states: S_c = sum_j e^{s_Q - s_j} dtx_j B_j^T
    tail = jnp.exp(cum[..., -1:, :] - cum)                  # (B,nC,Q,H)
    S_c = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", tail, dtx_c, B_c)
    # inter-chunk scan: H_c = e^{sum chunk} H_{c-1} + S_{c-1}
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nC,H)

    def scan_fn(h, inp):
        dec, s = inp
        h_new = h * dec[..., None, None] + s
        return h_new, h

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)
    s_t = jnp.moveaxis(S_c, 1, 0)
    h0 = jnp.zeros((B, H, P_, N), jnp.float32)
    h_final, h_prev = jax.lax.scan(scan_fn, h0, (dec_t, s_t))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nC,H,P,N)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         C_c, jnp.exp(cum), h_prev)
    y = (y_intra + y_inter).reshape(B, S, H, P_)[:, :S_orig]
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S_orig, inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = y * p["norm"]["scale"].astype(x.dtype)  # gated RMS-ish scale
    return dense(p["out_proj"], y), h_final, conv_tail


def ssd_decode(p, x, state, conv_cache, cfg):
    """x: (B, 1, D). state: (B, H, P, N); conv_cache: (B, cw-1, conv_ch)."""
    B = x.shape[0]
    z, xbc, dt, inner, N, H = _ssd_split(p, x, cfg)
    xbc, conv_cache = conv1d_step(p["conv"], xbc, conv_cache)
    xbc = jax.nn.silu(xbc)
    P_ = cfg.ssm_head_dim
    xs = xbc[..., :inner].reshape(B, H, P_)
    Bm = xbc[:, 0, inner:inner + N].astype(jnp.float32)
    Cm = xbc[:, 0, inner + N:].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["a_param"]) * dt)  # (B,H)
    dtx = xs.astype(jnp.float32) * dt[..., None]
    state = state * a[..., None, None] + \
        jnp.einsum("bhp,bn->bhpn", dtx, Bm)
    y = jnp.einsum("bhpn,bn->bhp", state, Cm)
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = y * jax.nn.silu(z) * p["norm"]["scale"].astype(x.dtype)
    return dense(p["out_proj"], y), state, conv_cache


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

RGLRU_C = 8.0


def rglru_init(key, cfg, dtype):
    D = cfg.d_model
    w = cfg.rnn_width or D
    ks = jax.random.split(key, 6)
    return {
        "gate_proj": dense_init(ks[0], D, w, dtype),   # gelu branch
        "in_proj": dense_init(ks[1], D, w, dtype),     # recurrent branch
        "conv": conv1d_init(ks[2], cfg.conv_width, w, dtype),
        "a_gate": dense_init(ks[3], w, w, dtype, bias=True),
        "x_gate": dense_init(ks[4], w, w, dtype, bias=True),
        "a_param": jnp.full((w,), 0.5, jnp.float32),   # Λ
        "out_proj": dense_init(ks[5], w, D, dtype),
    }


def _rglru_gates(p, xr):
    r = jax.nn.sigmoid(dense(p["a_gate"], xr).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["x_gate"], xr).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["a_param"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * \
        (i * xr.astype(jnp.float32))
    return a, gated


def rglru_forward(p, x, cfg):
    """x: (B, S, D) -> (y, final_state (B,w), conv_tail).
    Parallel over the sequence via associative scan."""
    gate = jax.nn.gelu(dense(p["gate_proj"], x))
    xr_raw = dense(p["in_proj"], x)
    cw = cfg.conv_width
    conv_tail = jnp.pad(xr_raw, ((0, 0), (max(0, cw - 1 - x.shape[1]), 0),
                                 (0, 0)))[:, -(cw - 1):]
    xr = conv1d(p["conv"], xr_raw)
    a, b = _rglru_gates(p, xr)  # h_t = a_t h_{t-1} + b_t

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = h.astype(x.dtype) * gate
    return dense(p["out_proj"], y), h[:, -1], conv_tail


def rglru_decode(p, x, state, conv_cache, cfg):
    """x: (B, 1, D); state: (B, w)."""
    gate = jax.nn.gelu(dense(p["gate_proj"], x))
    xr, conv_cache = conv1d_step(p["conv"], dense(p["in_proj"], x),
                                 conv_cache)
    a, b = _rglru_gates(p, xr)
    state = a[:, 0] * state + b[:, 0]
    y = state[:, None].astype(x.dtype) * gate
    return dense(p["out_proj"], y), state, conv_cache
