"""Elementary layers (pure functions over param pytrees; no framework)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd


def _split(key, n):
    return jax.random.split(key, n)


def dense_init(key, in_dim, out_shape, dtype, *, bias=False, scale=None):
    """w: (in_dim, *out_shape); fan-in scaled normal init."""
    if isinstance(out_shape, int):
        out_shape = (out_shape,)
    scale = scale if scale is not None else in_dim ** -0.5
    p = {"w": (jax.random.normal(key, (in_dim, *out_shape), jnp.float32)
               * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros(out_shape, dtype)
    return p


def dense(p, x, dims=1):
    """Contract the last ``dims``... here: last axis of x with first of w."""
    w = p["w"].astype(x.dtype)
    y = jnp.tensordot(x, w, axes=((x.ndim - 1,), (0,)))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta=10000.0):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def mlp_init(key, d_model, d_ff, dtype):
    k1, k2, k3 = _split(key, 3)
    return {"w1": dense_init(k1, d_model, d_ff, dtype),
            "w3": dense_init(k3, d_model, d_ff, dtype),
            "w2": dense_init(k2, d_ff, d_model, dtype)}


def mlp(p, x, layout="tp"):
    """SwiGLU MLP. layout='tp': hidden sharded over model (Megatron);
    layout='sp': tokens stay model-sharded, weights gathered."""
    h = jax.nn.silu(dense(p["w1"], x)) * dense(p["w3"], x)
    ba = shd.batch_axes() or None
    if layout == "sp" and h.ndim == 3:
        h = shd.constrain(h, ba, "model", None)
    else:
        h = shd.constrain(h, *([ba] + [None] * (h.ndim - 2) + ["model"]))
    return dense(p["w2"], h)


def embed_init(key, vocab, d_model, dtype):
    return {"w": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                  * d_model ** -0.5).astype(dtype)}


def embed_lookup(p, ids, compute_dtype):
    return p["w"].astype(compute_dtype)[ids]


def logits_head(p, x):
    """x: (B, S, D) -> (B, S, V), vocab sharded over model axis."""
    y = dense(p, x)
    return shd.constrain_batch(y, None, "model")


def cross_entropy(logits, labels, *, ignore_id=-1):
    """Stable CE; logits (B,S,V) possibly vocab-sharded (GSPMD handles the
    partial reductions)."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    # label log-prob via a masked reduction over the (model-sharded) vocab
    # dim — a take_along_axis here would force GSPMD to all-gather logits.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    hit = vocab_iota == labels[..., None].clip(0)
    ll = jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
