"""Model assembly: init / train forward / prefill / decode for any config.

Param tree layout (paths drive the sharding rules):
  embed/w                     (V, D)
  enc_g/...                   stacked encoder sublayers (whisper)
  enc_norm/scale
  lead{i}/...                 unscanned leading units (deepseek first-dense)
  g{j}/s{k}/...               stacked groups: repeat-dim-leading params
  norm/scale
  lm_head/w                   (D, V)
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.models.layers import (cross_entropy, embed_init, embed_lookup,
                                 dense_init, logits_head, rmsnorm,
                                 rmsnorm_init)

AUX_LOSS_COEF = 0.01

STACKED_RE = re.compile(r"^(g\d+|enc_g)$")


def _sinusoid(pos, d, dtype):
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = pos[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def _unit_init(key, pattern, cfg, use_moe, causal=True):
    ks = jax.random.split(key, len(pattern))
    return {f"s{i}": tf.sublayer_init(ks[i], kind, cfg, use_moe=use_moe)
            for i, kind in enumerate(pattern)}


def init_params(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    p = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
         "norm": rmsnorm_init(cfg.d_model, dt),
         "lm_head": dense_init(keys[1], cfg.d_model, cfg.vocab_size, dt)}
    if cfg.encoder_layers:
        ek = jax.random.split(keys[2], cfg.encoder_layers)
        p["enc_g"] = jax.vmap(
            lambda k: _unit_init(k, ("attn",), cfg, use_moe=False))(ek)
        p["enc_norm"] = rmsnorm_init(cfg.d_model, dt)
    for i in range(cfg.first_k_dense):
        p[f"lead{i}"] = _unit_init(jax.random.fold_in(keys[3], i),
                                   cfg.group_pattern, cfg, use_moe=False)
    for gi, (pattern, reps) in enumerate(cfg.groups):
        gk = jax.random.split(jax.random.fold_in(keys[4], gi), reps)
        p[f"g{gi}"] = jax.vmap(
            lambda k: _unit_init(k, pattern, cfg, use_moe=True))(gk)
    return p


def _groups(cfg):
    """[(name, pattern, reps), ...] for the decoder stack."""
    out = []
    for i in range(cfg.first_k_dense):
        out.append((f"lead{i}", cfg.group_pattern, None))
    for gi, (pattern, reps) in enumerate(cfg.groups):
        out.append((f"g{gi}", pattern, reps))
    return out


def _encode(params, cfg, enc_inp):
    """Whisper-style encoder over stub frame embeddings (B, Senc, D)."""
    x = enc_inp.astype(jnp.dtype(cfg.dtype))
    S = x.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S)[None], x.shape[:2])
    x = x + _sinusoid(pos, cfg.d_model, x.dtype)

    def body(x, pslice):
        x, _, _ = tf.sublayer_apply(pslice["s0"], "attn", x, pos, cfg,
                                    use_moe=False, causal=False)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_g"], unroll=cfg.scan_unroll)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def forward(params, cfg, tokens, *, enc_inp=None, pos0=0, cache=None,
            return_hidden=False):
    """Full-sequence forward. Returns (logits, aux, cache-or-None)."""
    cdt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = embed_lookup(params["embed"], tokens, cdt)
    x = shd.constrain_batch(x, None, None)
    pos = pos0 + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.pos_emb == "sinusoid":
        x = x + _sinusoid(pos, cfg.d_model, cdt)
    enc = None
    if cfg.encoder_layers:
        enc = _encode(params, cfg, enc_inp)
    elif enc_inp is not None:
        enc = enc_inp.astype(cdt)
    aux_total = jnp.float32(0)
    new_cache = {} if cache is not None else None

    for name, pattern, reps in _groups(cfg):
        use_moe = not name.startswith("lead")
        if reps is None:  # unscanned unit
            aux = jnp.float32(0)
            c_unit = cache.get(name) if cache is not None else None
            upd = {}
            for i, kind in enumerate(pattern):
                cs = c_unit[f"s{i}"] if c_unit is not None else None
                x, a, cs2 = tf.sublayer_apply(
                    params[name][f"s{i}"], kind, x, pos, cfg, enc=enc,
                    use_moe=use_moe, cache=cs)
                aux += a
                if cs2 is not None:
                    upd[f"s{i}"] = cs2
            aux_total += aux
            if cache is not None:
                new_cache[name] = upd
            continue

        def unit(x, pslice, cslice):
            aux = jnp.float32(0)
            upd = {}
            for i, kind in enumerate(pattern):
                cs = cslice[f"s{i}"] if cslice is not None else None
                x, a, cs2 = tf.sublayer_apply(
                    pslice[f"s{i}"], kind, x, pos, cfg, enc=enc,
                    use_moe=use_moe, cache=cs)
                aux += a
                upd[f"s{i}"] = cs2
            return x, aux, upd

        if cfg.remat == "block":
            unit = jax.checkpoint(unit)

        if cache is not None:
            def body(x, inp):
                pslice, cslice = inp
                x, aux, upd = unit(x, pslice, cslice)
                return x, (aux, upd)
            x, (auxs, updc) = jax.lax.scan(body, x,
                                           (params[name], cache[name]),
                                           unroll=cfg.scan_unroll)
            new_cache[name] = updc
        else:
            def body(x, pslice):
                x, aux, _ = unit(x, pslice, None)
                return x, aux
            x, auxs = jax.lax.scan(body, x, params[name],
                                   unroll=cfg.scan_unroll)
        aux_total += jnp.sum(auxs)

    x = rmsnorm(params["norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux_total, new_cache
    logits = logits_head(params["lm_head"], x)
    return logits, aux_total, new_cache


def _chunked_ce(params, cfg, x, labels):
    """Vocab head + CE in sequence chunks: the (B, Sc, V) logits block (and
    its f32 softmax temps) never exceeds one chunk; jax.checkpoint makes
    the backward recompute each chunk's logits instead of saving them."""
    B, S, D = x.shape
    C = min(cfg.ce_chunk, S)
    assert S % C == 0, (S, C)
    xc = x.reshape(B, S // C, C, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, S // C, C).transpose(1, 0, 2)

    @jax.checkpoint
    def one(x_blk, l_blk):
        logits = logits_head(params["lm_head"], x_blk)
        mask = (l_blk != -1).astype(jnp.float32)
        return cross_entropy(logits, l_blk) * jnp.maximum(mask.sum(), 1.0), \
            mask.sum()

    def body(carry, inp):
        tot, cnt = carry
        s, n = one(*inp)
        return (tot + s, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params, cfg, batch):
    """batch: {'tokens': (B,S), 'labels': (B,S)} (+ 'enc_inp')."""
    if cfg.ce_chunk:
        x, aux, _ = forward(params, cfg, batch["tokens"],
                            enc_inp=batch.get("enc_inp"),
                            return_hidden=True)
        loss = _chunked_ce(params, cfg, x, batch["labels"])
    else:
        logits, aux, _ = forward(params, cfg, batch["tokens"],
                                 enc_inp=batch.get("enc_inp"))
        loss = cross_entropy(logits, batch["labels"])
    return loss + AUX_LOSS_COEF * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: cache shapes / prefill / decode
# ---------------------------------------------------------------------------

def cache_shapes(cfg, batch, smax, enc_len=0):
    out = {}
    for name, pattern, reps in _groups(cfg):
        unit = {f"s{i}": tf.sublayer_cache(kind, cfg, batch, smax, enc_len)
                for i, kind in enumerate(pattern)}
        if reps is not None:
            unit = jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype),
                unit)
        out[name] = unit
    return out


def init_cache(cfg, batch, smax, enc_len=0):
    return tf.zeros_like_sds(cache_shapes(cfg, batch, smax, enc_len))


def prefill(params, cfg, tokens, cache, *, enc_inp=None):
    """Process the prompt; returns (last-token logits, populated cache)."""
    logits, _, cache = forward(params, cfg, tokens, enc_inp=enc_inp,
                               cache=cache)
    return logits[:, -1], cache


def decode_step(params, cfg, token, cache, cache_len, *, enc_inp=None):
    """token: (B, 1). Returns (logits (B, V), new_cache)."""
    cdt = jnp.dtype(cfg.dtype)
    B = token.shape[0]
    x = embed_lookup(params["embed"], token, cdt)
    if cfg.pos_emb == "sinusoid":
        pos = jnp.full((B, 1), cache_len, jnp.int32)
        x = x + _sinusoid(pos, cfg.d_model, cdt)
    new_cache = {}
    for name, pattern, reps in _groups(cfg):
        use_moe = not name.startswith("lead")
        if reps is None:
            upd = {}
            for i, kind in enumerate(pattern):
                x, cs, _ = tf.sublayer_decode(
                    params[name][f"s{i}"], kind, x, cache[name][f"s{i}"],
                    cache_len, cfg, use_moe=use_moe)
                upd[f"s{i}"] = cs
            new_cache[name] = upd
            continue

        def body(x, inp):
            pslice, cslice = inp
            upd = {}
            for i, kind in enumerate(pattern):
                x, cs, _ = tf.sublayer_decode(
                    pslice[f"s{i}"], kind, x, cslice[f"s{i}"],
                    cache_len, cfg, use_moe=use_moe)
                upd[f"s{i}"] = cs
            return x, upd

        x, updc = jax.lax.scan(body, x, (params[name], cache[name]),
                               unroll=cfg.scan_unroll)
        new_cache[name] = updc
    x = rmsnorm(params["norm"], x, cfg.norm_eps)
    logits = logits_head(params["lm_head"], x)
    return logits[:, -1], new_cache
