"""Mixture-of-Experts with **zipper dispatch** — the paper's stream-sort
primitive as a first-class framework feature.

Token→expert routing is a key-value stream problem: keys = expert ids,
values = token slots. Dispatch = sort the stream by key (mssortk/mssortv
semantics, minus duplicate merging — tokens must be grouped, not summed),
then exchange grouped tokens across expert-parallel shards.

Two paths:

  zipper (production): shard_map over the mesh. Tokens are split over the
    model axis inside the MoE region (sequence parallelism), sorted by
    expert id with the zipper-sort primitive, packed into per-expert
    capacity bins, exchanged with a single all_to_all over the model axis
    (experts are model-sharded), run through batched expert FFNs, and
    combined back through the inverse permutation. Expert weights can be
    FSDP-sharded over the data axis and are all-gathered inside the region
    (ZeRO-3; the gather overlaps with routing on real hardware).

  einsum (reference): dense one-hot dispatch for tiny smoke configs and
    numerics cross-checks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.kernels import ops as kops
from repro.models.layers import dense_init, mlp, mlp_init


def moe_init(key, cfg, dtype):
    E = cfg.num_experts
    D, F = cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], D, E, jnp.float32),
        "experts": {
            "w1": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * D ** -0.5).astype(dtype),
            "w3": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * D ** -0.5).astype(dtype),
            "w2": (jax.random.normal(ks[3], (E, F, D), jnp.float32) * F ** -0.5).astype(dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks[4], D, F * cfg.num_shared_experts, dtype)
    if cfg.dense_residual:
        p["dense_mlp"] = mlp_init(ks[5], D, cfg.d_ff, dtype)
    return p


def _router(p, x, cfg):
    """x: (..., D) -> (topk ids (..., k), weights (..., k), logits)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), p["router"]["w"])
    w, ids = jax.lax.top_k(logits, cfg.top_k)
    w = jax.nn.softmax(w, axis=-1)
    return ids.astype(jnp.int32), w, logits


def _expert_ffn(we, xe):
    """xe: (E_loc, C, D); we: dict of (E_loc, D, F)/(E_loc, F, D)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, we["w1"].astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, we["w3"].astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", h, we["w2"].astype(xe.dtype))


def _capacity(T, k, E, cf):
    """Per-expert capacity. Small token counts (decode steps, smoke tests)
    get a dropless capacity so decode matches the full forward exactly."""
    if T * k <= 256:
        return T * k
    return -(-max(8, int(cf * T * k / E)) // 8) * 8


def _aux_loss(logits, ids, cfg):
    """Switch-style load-balance loss."""
    E = cfg.num_experts
    probs = jax.nn.softmax(logits, axis=-1).reshape(-1, E)
    hot = jax.nn.one_hot(ids.reshape(-1), E, dtype=jnp.float32)
    frac_tokens = hot.mean(0)
    frac_prob = probs.mean(0)
    return E * jnp.sum(frac_tokens * frac_prob)


# ---------------------------------------------------------------------------
# zipper dispatch
# ---------------------------------------------------------------------------

def moe_block(p, x, cfg, *, dispatch=None):
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    dispatch = dispatch or cfg.moe_dispatch
    out_parts = []
    if cfg.dense_residual:
        out_parts.append(mlp(p["dense_mlp"], x, layout=cfg.layer_layout))
    if cfg.num_shared_experts:
        out_parts.append(mlp(p["shared"], x, layout=cfg.layer_layout))
    if dispatch == "einsum" or shd.get_mesh() is None:
        routed, aux = _einsum_moe(p, x, cfg)
    else:
        routed, aux = _shardmap_moe(p, x, cfg)
    out_parts.append(routed)
    return functools.reduce(jnp.add, out_parts), aux


def _einsum_moe(p, x, cfg):
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(-1, D)
    ids, w, logits = _router(p, xt, cfg)
    T = xt.shape[0]
    cap = _capacity(T, k, E, cfg.capacity_factor)
    # zipper-sort the (expert, slot) stream — paper primitive, XLA/Pallas path
    flat_ids = ids.reshape(-1)  # (T*k)
    _, perm = kops.sort_tokens_by_key(flat_ids, backend="xla")
    sorted_ids = flat_ids[perm]
    # position of each assignment within its expert group
    hot = jax.nn.one_hot(sorted_ids, E, dtype=jnp.int32)
    pos_sorted = (jnp.cumsum(hot, axis=0) - hot)[jnp.arange(T * k), sorted_ids]
    pos = jnp.zeros(T * k, jnp.int32).at[perm].set(pos_sorted)
    keep = pos < cap
    buf = jnp.zeros((E, cap, D), x.dtype)
    tok = jnp.repeat(jnp.arange(T), k)
    buf = buf.at[flat_ids, jnp.where(keep, pos, 0)].add(
        jnp.where(keep[:, None], xt[tok], 0))
    ye = _expert_ffn(p["experts"], buf)
    yt = ye[flat_ids, jnp.where(keep, pos, 0)]
    yt = jnp.where(keep[:, None], yt, 0) * w.reshape(-1)[:, None].astype(x.dtype)
    out = jnp.zeros_like(xt).at[tok].add(yt)
    return out.reshape(B, S, D), _aux_loss(logits, ids, cfg)


def _shardmap_moe(p, x, cfg):
    """Production path: shard_map(zipper sort + all_to_all EP)."""
    mesh = shd.get_mesh()
    ba = shd.batch_axes()
    n_model = shd.model_axis_size()
    E = cfg.num_experts
    B, S, D = x.shape
    k = cfg.top_k
    fsdp = cfg.fsdp and "data" in mesh.axis_names
    # sequence-shard tokens over the model axis when the shape allows it
    # (training/prefill); decode (S < n_model) replicates routing over the
    # model axis — expert FFNs stay sharded either way.
    seq_shard = S % n_model == 0 and S >= n_model
    s_div = n_model if seq_shard else 1
    b_div = max(1, shd.data_axis_size()) if B % max(1, shd.data_axis_size()) == 0 else 1

    T_loc = (B // b_div) * (S // s_div)
    cap = _capacity(T_loc, k, E, cfg.capacity_factor)
    E_loc = E // n_model

    we = p["experts"]
    w_spec = P("model", "data", None) if fsdp else P("model", None, None)
    w2_spec = P("model", None, "data") if fsdp else P("model", None, None)

    def body(wr, w1, w3, w2, xl):
        # xl: (B_loc, S_loc, D); w1/w3: (E_loc, D[/dp], F); wr: (D, E)
        if fsdp:
            w1 = jax.lax.all_gather(w1, "data", axis=1, tiled=True)
            w3 = jax.lax.all_gather(w3, "data", axis=1, tiled=True)
            w2 = jax.lax.all_gather(w2, "data", axis=2, tiled=True)
        bl, sl, _ = xl.shape
        xt = xl.reshape(-1, D)
        T = xt.shape[0]
        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), wr)
        wk, ids = jax.lax.top_k(logits, k)
        wk = jax.nn.softmax(wk, axis=-1)
        flat_ids = ids.reshape(-1).astype(jnp.int32)
        # ---- zipper sort (mssortk/mssortv semantics, group-not-merge) ----
        _, perm = kops.sort_tokens_by_key(flat_ids, backend="xla")
        sorted_ids = flat_ids[perm]
        hot = jax.nn.one_hot(sorted_ids, E, dtype=jnp.int32)
        pos_sorted = (jnp.cumsum(hot, axis=0) - hot)[
            jnp.arange(T * k), sorted_ids]
        keep = pos_sorted < cap
        tok_sorted = perm // k
        buf = jnp.zeros((E, cap, D), xl.dtype)
        buf = buf.at[sorted_ids, jnp.where(keep, pos_sorted, 0)].add(
            jnp.where(keep[:, None], xt[tok_sorted], 0))
        # ---- EP exchange: (E, cap, D) -> (E_loc, n_model * cap, D) ----
        xe = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                tiled=True)
        ye = _expert_ffn({"w1": w1, "w3": w3, "w2": w2}, xe)
        # ---- reverse exchange (exact inverse of the tiled all_to_all) ----
        ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0,
                                tiled=True)
        y_sorted = ye[sorted_ids, jnp.where(keep, pos_sorted, 0)]
        y_sorted = jnp.where(keep[:, None], y_sorted, 0)
        # ---- combine: inverse zipper permutation + top-k weighting ----
        y_flat = jnp.zeros((T * k, D), xl.dtype).at[perm].set(y_sorted)
        y = (y_flat.reshape(T, k, D) *
             wk[..., None].astype(xl.dtype)).sum(1)
        # aux loss (local estimate; mean over data axes happens in caller)
        probs = jax.nn.softmax(logits, axis=-1)
        frac_t = jax.nn.one_hot(ids.reshape(-1), E, dtype=jnp.float32).mean(0)
        aux = E * jnp.sum(frac_t * probs.mean(0))
        aux = jax.lax.pmean(aux, "model")
        for a in ba:
            aux = jax.lax.pmean(aux, a)
        return y.reshape(bl, sl, D), aux

    from jax.experimental.shard_map import shard_map
    x_spec = P(ba if (ba and B % max(1, shd.data_axis_size()) == 0) else None,
               "model" if seq_shard else None, None)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), w_spec, w_spec, w2_spec, x_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(p["router"]["w"], we["w1"], we["w3"], we["w2"], x)
    return y, aux
