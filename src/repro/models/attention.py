"""Attention: GQA (+bias, local windows, cross) and MLA (DeepSeek-V2).

Training/prefill uses a blocked online-softmax attention (flash-style in
pure lax, memory O(S·block)); an optional static causal block-skip halves
the FLOPs (hillclimb flag ``attn_block_skip``). Decode attends a KV cache
whose *sequence* dim is sharded over the model axis — GSPMD turns the
softmax over the sharded dim into the flash-decode partial-softmax pattern
(per-shard max/sum + tiny all-reduces), which is how we use 16-way model
parallelism even when kv_heads < 16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, (cfg.num_heads, hd), dtype,
                         bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], cfg.d_model, (cfg.num_kv_heads, hd), dtype,
                         bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], cfg.d_model, (cfg.num_kv_heads, hd), dtype,
                         bias=cfg.qkv_bias),
        "wo": {"w": (jax.random.normal(ks[3], (cfg.num_heads, hd, cfg.d_model),
                                       jnp.float32)
                     * (cfg.num_heads * hd) ** -0.5).astype(dtype)},
    }


def mla_init(key, cfg, dtype):
    ks = jax.random.split(key, 7)
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": rmsnorm_init(cfg.q_lora_rank, dtype),
        "w_uq": dense_init(ks[1], cfg.q_lora_rank, (cfg.num_heads, qk), dtype),
        "w_dkv": dense_init(ks[2], cfg.d_model,
                            cfg.kv_lora_rank + cfg.qk_rope_dim, dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[3], cfg.kv_lora_rank,
                           (cfg.num_heads, cfg.qk_nope_dim), dtype),
        "w_uv": dense_init(ks[4], cfg.kv_lora_rank,
                           (cfg.num_heads, cfg.v_head_dim), dtype),
        "wo": {"w": (jax.random.normal(
            ks[5], (cfg.num_heads, cfg.v_head_dim, cfg.d_model), jnp.float32)
            * (cfg.num_heads * cfg.v_head_dim) ** -0.5).astype(dtype)},
    }


# ---------------------------------------------------------------------------
# blocked online-softmax attention (training / prefill)
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, qpos, kpos, causal, window, scale, p_bf16=False):
    """q: (B,qb,H,hd) k/v: (B,kb,KVH,hd) -> partial (acc, m, l)."""
    B, qb, H, hd = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qg = q.reshape(B, qb, KVH, G, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.ones((qb, k.shape[1]), bool)
    dpos = qpos[:, None] - kpos[None, :]
    if causal:
        mask &= dpos >= 0
    if window:
        mask &= dpos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,KVH,G,qb)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    if p_bf16:
        # flash-attention-2 numerics: bf16 probabilities between the
        # softmax and the PV matmul (halves score-chain traffic)
        p = p.astype(jnp.bfloat16)
    acc = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(p.dtype),
                     preferred_element_type=jnp.float32)
    return acc, m, l


def blocked_attention(q, k, v, *, causal=True, window=0, q_block=2048,
                      kv_block=1024, block_skip=False, q_offset=0,
                      scale=None, p_bf16=False):
    """q: (B,Sq,H,hd); k/v: (B,Skv,KVH,hd). Returns (B,Sq,H,hd).

    q_offset: global position of q[0] minus position of k[0] (prefill: 0
    when Sq == Skv; decode chunks: cache_len)."""
    B, Sq, H, hd = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    nq = -(-Sq // qb)
    nk = -(-Skv // kb)
    pad_q = nq * qb - Sq
    pad_k = nk * kb - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    kpos_all = jnp.arange(nk * kb)
    valid_k = kpos_all < Skv

    def q_block_fn(i, qi):
        qpos = i * qb + jnp.arange(qb) + q_offset

        def kv_step(carry, j):
            acc, m, l = carry
            ks = jax.lax.dynamic_slice_in_dim(k, j * kb, kb, 1)
            vs = jax.lax.dynamic_slice_in_dim(v, j * kb, kb, 1)
            kpos = j * kb + jnp.arange(kb)
            kpos = jnp.where(jax.lax.dynamic_slice_in_dim(valid_k, j * kb, kb, 0),
                             kpos, Sq + Skv + 10**9)  # mask padding
            a2, m2, l2 = _attend_block(qi, ks, vs, qpos, kpos, causal,
                                       window, scale, p_bf16)
            mn = jnp.maximum(m, m2)
            c1 = jnp.exp(m - mn)
            c2 = jnp.exp(m2 - mn)
            acc = acc * c1[..., None] + a2 * c2[..., None]
            l = l * c1 + l2 * c2
            return (acc, mn, l), None

        G = H // KVH
        hd_v = v.shape[-1]
        acc0 = jnp.zeros((B, KVH, G, qb, hd_v), jnp.float32)
        m0 = jnp.full((B, KVH, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qb), jnp.float32)
        if block_skip and causal:
            # static skip: kv block j only if j*kb <= (i+1)*qb - 1 + offset
            hi = min(nk, -(-((i + 1) * qb + q_offset) // kb))
            carry = (acc0, m0, l0)
            for j in range(hi):
                carry, _ = kv_step(carry, j)
            acc, m, l = carry
        else:
            (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                          jnp.arange(nk))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.reshape(B, KVH * G, qb, hd_v).transpose(0, 2, 1, 3)

    outs = [q_block_fn(i, q[:, i * qb:(i + 1) * qb]) for i in range(nq)]
    out = jnp.concatenate(outs, axis=1)[:, :Sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block forward
# ---------------------------------------------------------------------------

def gqa_forward(p, x, pos, cfg, *, causal=True, window=0, kv_override=None):
    """Full-sequence (train/prefill) GQA. kv_override: encoder states for
    cross-attention (B, Senc, D)."""
    q = dense(p["wq"], x)
    src = kv_override if kv_override is not None else x
    k = dense(p["wk"], src)
    v = dense(p["wv"], src)
    ba = shd.batch_axes() or None
    if cfg.layer_layout == "sp":
        # tokens stay model-sharded; K/V (small under GQA) are gathered to
        # full sequence per device, Q/out keep the sequence sharding
        q = shd.constrain(q, ba, "model", None, None)
        k = shd.constrain(k, ba, None, None, None)
        v = shd.constrain(v, ba, None, None, None)
    else:
        q = shd.constrain(q, ba, None, "model", None)
        k = shd.constrain(k, ba, None, "model" if cfg.num_kv_heads >= shd.model_axis_size() else None, None)
    if kv_override is None:
        if cfg.pos_emb == "rope":
            q = rope(q, pos, cfg.rope_theta)
            k = rope(k, pos, cfg.rope_theta)
        if cfg.attn_impl == "pallas":
            from repro.kernels.flash_attention import flash_attention_pallas
            out = flash_attention_pallas(
                q, k, v, causal=causal, window=window,
                interpret=jax.default_backend() != "tpu")
        else:
            out = blocked_attention(q, k, v, causal=causal, window=window,
                                    q_block=cfg.attn_q_block,
                                    kv_block=cfg.attn_kv_block,
                                    block_skip=cfg.attn_block_skip,
                                    p_bf16=cfg.attn_p_bf16)
    else:
        out = blocked_attention(q, k, v, causal=False,
                                q_block=cfg.attn_q_block,
                                kv_block=cfg.attn_kv_block)
    if cfg.layer_layout == "sp":
        out = shd.constrain(out, ba, "model", None, None)
    else:
        out = shd.constrain(out, ba, None, "model", None)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"]["w"].astype(x.dtype))


def gqa_decode(p, x, cache_k, cache_v, cache_len, cfg, *, window=0,
               kv_override=False):
    """One-token decode. cache_k/v: (B, Smax, KVH, hd) with the sequence dim
    sharded over the model axis (see module docstring). Returns
    (out, new_k, new_v)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    q = dense(p["wq"], x)
    if cfg.pos_emb == "rope":
        q = rope(q, pos, cfg.rope_theta)
    if not kv_override:
        k_new = dense(p["wk"], x)
        if cfg.pos_emb == "rope":
            k_new = rope(k_new, pos, cfg.rope_theta)
        v_new = dense(p["wv"], x)
        Smax = cache_k.shape[1]
        if cfg.decode_dus:
            cache_k = jax.lax.dynamic_update_slice_in_dim(
                cache_k, k_new.astype(cache_k.dtype), cache_len, 1)
            cache_v = jax.lax.dynamic_update_slice_in_dim(
                cache_v, v_new.astype(cache_v.dtype), cache_len, 1)
        else:
            onehot = (jnp.arange(Smax) == cache_len).astype(cache_k.dtype)
            cache_k = cache_k * (1 - onehot)[None, :, None, None] + \
                k_new.astype(cache_k.dtype) * onehot[None, :, None, None]
            cache_v = cache_v * (1 - onehot)[None, :, None, None] + \
                v_new.astype(cache_v.dtype) * onehot[None, :, None, None]
    ba = shd.batch_axes() or None
    cache_k = shd.constrain(cache_k, ba, "model", None, None)
    cache_v = shd.constrain(cache_v, ba, "model", None, None)
    Smax = cache_k.shape[1]
    KVH = cache_k.shape[2]
    G = cfg.num_heads // KVH
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   cache_k.astype(jnp.float32)) * hd ** -0.5
    kpos = jnp.arange(Smax)
    valid = kpos <= cache_len if not kv_override else kpos < cache_len
    if window:
        valid &= kpos > cache_len - window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    pbs = jax.nn.softmax(s, axis=-1)  # GSPMD: partial softmax + all-reduce
    out = jnp.einsum("bkgs,bskd->bkgd", pbs, cache_v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads, hd).astype(x.dtype)
    y = jnp.einsum("bshd,hdo->bso", out, p["wo"]["w"].astype(x.dtype))
    return y[:, 0:1].reshape(B, 1, -1), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA forward (DeepSeek-V2): compressed KV cache + absorbed decode
# ---------------------------------------------------------------------------

def mla_forward(p, x, pos, cfg):
    B, S, D = x.shape
    cq = rmsnorm(p["q_norm"], dense(p["w_dq"], x), cfg.norm_eps)
    q = dense(p["w_uq"], cq)  # (B,S,H,nope+rope)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)
    dkv = dense(p["w_dkv"], x)
    c_kv = rmsnorm(p["kv_norm"], dkv[..., :cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = rope(dkv[..., None, cfg.kv_lora_rank:], pos, cfg.rope_theta)
    k_nope = dense(p["w_uk"], c_kv)  # (B,S,H,nope)
    v = dense(p["w_uv"], c_kv)       # (B,S,H,vd)
    H = cfg.num_heads
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (B, S, H, cfg.qk_rope_dim))],
                        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    ba = shd.batch_axes() or None
    # MLA-specific layout: the per-head K/V blow-up (H x (nope+rope) per
    # token) must be head-sharded; the only tensor worth gathering is the
    # *compressed* c_kv (r + rope per token) — which is the whole point of
    # MLA. This holds for both tp and sp residual layouts.
    q_full = shd.constrain(q_full, ba, None, "model", None)
    k = shd.constrain(k, ba, None, "model", None)
    v = shd.constrain(v, ba, None, "model", None)
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    # pad v head dim up to qk dim for the shared blocked kernel
    out = blocked_attention(q_full, k, v, causal=True, scale=scale,
                            q_block=cfg.attn_q_block,
                            kv_block=cfg.attn_kv_block,
                            block_skip=cfg.attn_block_skip,
                            p_bf16=cfg.attn_p_bf16)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"]["w"].astype(x.dtype))


def mla_decode(p, x, cache_c, cache_kr, cache_len, cfg):
    """Absorbed MLA decode: scores and context in the compressed space.
    cache_c: (B, Smax, r); cache_kr: (B, Smax, rope)."""
    B = x.shape[0]
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    cq = rmsnorm(p["q_norm"], dense(p["w_dq"], x), cfg.norm_eps)
    q = dense(p["w_uq"], cq)[:, 0]  # (B,H,nope+rope)
    q_nope, q_rope = q[..., :cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = rope(q_rope[:, None], pos, cfg.rope_theta)[:, 0]
    dkv = dense(p["w_dkv"], x)
    c_new = rmsnorm(p["kv_norm"], dkv[..., :cfg.kv_lora_rank], cfg.norm_eps)
    kr_new = rope(dkv[..., None, cfg.kv_lora_rank:], pos,
                  cfg.rope_theta)[..., 0, :]
    Smax = cache_c.shape[1]
    if cfg.decode_dus:
        cache_c = jax.lax.dynamic_update_slice_in_dim(
            cache_c, c_new.astype(cache_c.dtype), cache_len, 1)
        cache_kr = jax.lax.dynamic_update_slice_in_dim(
            cache_kr, kr_new.astype(cache_kr.dtype), cache_len, 1)
    else:
        onehot = (jnp.arange(Smax) == cache_len).astype(cache_c.dtype)
        cache_c = cache_c * (1 - onehot)[None, :, None] + \
            c_new[:, 0][:, None] * onehot[None, :, None]
        cache_kr = cache_kr * (1 - onehot)[None, :, None] + \
            kr_new[:, 0][:, None] * onehot[None, :, None]
    ba = shd.batch_axes() or None
    cache_c = shd.constrain(cache_c, ba, "model", None)
    cache_kr = shd.constrain(cache_kr, ba, "model", None)
    # absorb w_uk into q: q' = q_nope @ w_uk^T  -> (B,H,r)
    qc = jnp.einsum("bhn,rhn->bhr", q_nope, p["w_uk"]["w"].astype(x.dtype))
    s = jnp.einsum("bhr,bsr->bhs", qc.astype(jnp.float32),
                   cache_c.astype(jnp.float32))
    s += jnp.einsum("bhe,bse->bhs", q_rope.astype(jnp.float32),
                    cache_kr.astype(jnp.float32))
    s *= (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    valid = jnp.arange(Smax) <= cache_len
    s = jnp.where(valid[None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pr, cache_c.astype(jnp.float32))
    v = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype),
                   p["w_uv"]["w"].astype(x.dtype))
    y = jnp.einsum("bhv,hvo->bo", v, p["wo"]["w"].astype(x.dtype))
    return y[:, None], cache_c, cache_kr
