"""Learned cost-model dispatch: per-candidate runtime regression.

The autotune cache accumulates, per shape/nnz bucket, the full timing
vector of every ``_measure`` sweep (every "engine|backend" combo timed)
plus the structural feature dict the sweep saw.  This module turns that
dataset into the selection model ``core/dispatch.py`` consults between
cache-hit and heuristics: a tiny log-linear regressor

    log t(combo) = w[combo] . z + b[combo]

over standardized log-transformed ``work_stats`` features, one weight
row per candidate combo, trained with the repo's own AdamW
(``repro/optim/adamw.py``) on masked squared error (a sweep only times
the combos that were healthy at the time, so the target matrix is
ragged).  Selection is an argmin over predicted runtimes with a
calibrated confidence — the probability the top pick truly beats the
runner-up, given the model's residual noise ``sigma`` on log-runtime:

    confidence = Phi((log t2 - log t1) / (sigma * sqrt(2)))

A prediction below the confidence floor abstains, and ``plan()`` falls
through to measurement (which feeds the dataset) or heuristics.

Trained models persist as a small versioned JSON artifact next to the
cache file (``<cache>.model.json``); ``train_and_save`` bumps the
artifact version monotonically so dispatch's mtime-keyed memo and the
plan memo both see retrains.  This module deliberately does not import
``core/dispatch`` (dispatch lazily imports *us*); the only shared
contract is the "engine|backend" combo string and the entry schema
``{"timings": {combo: seconds}, "features": {...}}``.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import tempfile
from typing import Iterable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import adamw

FORMAT_VERSION = 1          # artifact schema (load refuses newer formats)
ARTIFACT_KIND = "dispatch-cost-model"

# feature order is part of the artifact contract — new features append
FEATURE_NAMES: tuple[str, ...] = (
    "nnz", "density", "avg_work_per_row", "avg_work_per_group",
    "work_var_per_group", "total_work",
)

# work_var_per_group is already a dimensionless ratio; everything else
# spans orders of magnitude and regresses on a log scale
_LOG1P = {"nnz", "avg_work_per_row", "avg_work_per_group", "total_work"}
_LOG_EPS = {"density": 1e-12}

_SIGMA_FLOOR = 0.05         # log-runtime noise floor (≈5% runtime)
_T_FLOOR = 1e-9             # sub-ns timings are clock noise


def split_combo(combo: str) -> tuple[str, Optional[str]]:
    """"engine|backend" → (engine, backend-or-None); mirrors dispatch."""
    engine, _, backend = combo.partition("|")
    return engine, (backend or None)


def featurize(feats: dict) -> list[float]:
    """Raw feature dict → the model's (d,) transformed input vector.

    Plain-Python on purpose: this runs on the plan hot path, where at
    d=6 the per-call numpy dispatch overhead costs more than the math."""
    out = []
    for name in FEATURE_NAMES:
        v = float(feats.get(name, 0.0))
        if not math.isfinite(v):
            v = 0.0
        if name in _LOG1P:
            v = math.log1p(max(v, 0.0))
        elif name in _LOG_EPS:
            v = math.log(max(v, 0.0) + _LOG_EPS[name])
        out.append(v)
    return out


def samples_from_entries(entries: dict) -> list[dict]:
    """Extract the training dataset from an autotune-cache snapshot
    (``AutotuneCache.entries()`` or a raw loaded cache file): one sample
    per bucket that recorded a timing vector + features.  Winner-only
    entries (heuristic puts, migrated v1 entries) and reserved keys
    ("!quarantine:", "!schema") carry no regression target and are
    skipped."""
    samples = []
    for key in sorted(entries):
        e = entries[key]
        if key.startswith("!") or not isinstance(e, dict):
            continue
        timings, feats = e.get("timings"), e.get("features")
        if not timings or not feats:
            continue
        clean = {c: float(t) for c, t in timings.items()
                 if isinstance(t, (int, float)) and math.isfinite(t)
                 and t > 0.0}
        if not clean:
            continue
        samples.append({"key": key, "features": dict(feats),
                        "timings": clean})
    return samples


@dataclasses.dataclass(frozen=True)
class Selection:
    """One model-based selection: the argmin combo, how sure the model
    is, and the full predicted cost surface (seconds per combo)."""

    engine: str
    backend: Optional[str]
    combo: str
    confidence: float           # P(top pick beats the runner-up)
    confident: bool             # clears the floor AND covers all combos
    costs: dict                 # combo -> predicted seconds


@functools.partial(jax.jit, static_argnums=0)
def _train_step(cfg: adamw.AdamWConfig, params, opt_state, Z, Y, M):
    """One AdamW step on masked squared error over log-runtimes."""
    def loss_fn(p):
        pred = Z @ p["w"].T + p["bias"]
        se = jnp.square(pred - Y) * M
        return se.sum() / jnp.maximum(M.sum(), 1.0)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state, _ = adamw.apply_updates(cfg, params, opt_state,
                                               grads)
    return params, opt_state, loss


class DispatchModel:
    """Per-candidate log-linear runtime model with calibrated argmin."""

    def __init__(self, *, candidates: list, w: np.ndarray, bias: np.ndarray,
                 mean: np.ndarray, std: np.ndarray, sigma: float,
                 confidence_floor: float = 0.7, version: int = 1,
                 n_samples: int = 0, train_loss: Optional[float] = None):
        self.candidates = list(candidates)
        self.w = np.asarray(w, np.float64).reshape(len(candidates),
                                                   len(FEATURE_NAMES))
        self.bias = np.asarray(bias, np.float64).reshape(len(candidates))
        self.mean = np.asarray(mean, np.float64).reshape(len(FEATURE_NAMES))
        self.std = np.asarray(std, np.float64).reshape(len(FEATURE_NAMES))
        self.sigma = max(float(sigma), _SIGMA_FLOOR)
        self.confidence_floor = float(confidence_floor)
        self.version = int(version)
        self.n_samples = int(n_samples)
        self.train_loss = train_loss
        # plain-list mirrors of the parameters for the hot inference
        # path: at (C≈5, d=6) python loops beat numpy dispatch overhead
        # by ~30µs per plan, which is most of the select budget
        self._w_rows = [list(r) for r in self.w]
        self._bias_l = list(self.bias)
        self._mean_l = list(self.mean)
        self._inv_std_l = [1.0 / s if s > 1e-12 else 1.0
                           for s in self.std]

    # -- inference ---------------------------------------------------------

    def predict(self, feats: dict) -> dict:
        """Predicted runtime in seconds for every known combo."""
        x = featurize(feats)
        z = [(xi - m) * s for xi, m, s in zip(x, self._mean_l,
                                              self._inv_std_l)]
        out = {}
        for c, row, b in zip(self.candidates, self._w_rows, self._bias_l):
            t = b + sum(wi * zi for wi, zi in zip(row, z))
            out[c] = math.exp(min(t, 50.0))
        return out

    def select(self, feats: dict,
               allowed: Optional[Iterable[str]] = None) -> Optional[Selection]:
        """Argmin over predicted runtimes, restricted to ``allowed``
        combos (the caller's healthy candidate set).

        Confidence is the probability the winner truly beats the
        runner-up under independent N(0, sigma^2) errors on the two
        log-runtime predictions.  The selection is only ``confident``
        when that clears the floor AND the model has costs for *every*
        allowed combo — a combo the model never saw cannot be ranked,
        so the caller should measure instead.  Returns None when no
        allowed combo is known at all."""
        costs = self.predict(feats)
        unknown: set = set()
        if allowed is not None:
            allowed = set(allowed)
            unknown = allowed - set(costs)
            costs = {c: t for c, t in costs.items() if c in allowed}
        if not costs:
            return None
        order = sorted(costs, key=costs.get)
        best = order[0]
        if len(order) == 1:
            confidence = 1.0
        else:
            gap = math.log(costs[order[1]]) - math.log(costs[best])
            confidence = 0.5 * (1.0 + math.erf(
                gap / (self.sigma * math.sqrt(2.0) * math.sqrt(2.0))))
        engine, backend = split_combo(best)
        return Selection(engine=engine, backend=backend, combo=best,
                         confidence=confidence,
                         confident=(not unknown
                                    and confidence >= self.confidence_floor),
                         costs=costs)

    # -- training ----------------------------------------------------------

    @classmethod
    def train(cls, samples: list, *, steps: int = 400, lr: float = 0.05,
              weight_decay: float = 1e-4, confidence_floor: float = 0.7,
              version: int = 1) -> Optional["DispatchModel"]:
        """Fit from ``samples_from_entries`` output; None when empty.

        The target matrix is ragged (each sweep only timed the combos
        healthy at the time), so the loss masks unobserved cells.  Rows
        are padded to a power of two so every fold of a
        leave-one-bucket-out eval reuses one compiled train step."""
        samples = [s for s in samples
                   if s.get("timings") and s.get("features")]
        if not samples:
            return None
        candidates = sorted({c for s in samples for c in s["timings"]})
        cidx = {c: j for j, c in enumerate(candidates)}
        n, C, d = len(samples), len(candidates), len(FEATURE_NAMES)
        X = np.stack([featurize(s["features"]) for s in samples])
        std = X.std(0)
        mean, std = X.mean(0), np.where(std < 1e-6, 1.0, std)
        Z = (X - mean) / std
        Y = np.zeros((n, C))
        M = np.zeros((n, C))
        for i, s in enumerate(samples):
            for c, t in s["timings"].items():
                Y[i, cidx[c]] = math.log(max(float(t), _T_FLOOR))
                M[i, cidx[c]] = 1.0
        # pow2 row padding: one jit shape serves every LOBO fold
        n_pad = 1 << max(2, int(n - 1).bit_length())
        Zp = np.zeros((n_pad, d))
        Yp = np.zeros((n_pad, C))
        Mp = np.zeros((n_pad, C))
        Zp[:n], Yp[:n], Mp[:n] = Z, Y, M
        col_n = np.maximum(M.sum(0), 1.0)
        b0 = (Y * M).sum(0) / col_n   # start at per-candidate mean log-t
        params = {"w": jnp.zeros((C, d), jnp.float32),
                  "bias": jnp.asarray(b0, jnp.float32)}
        cfg = adamw.AdamWConfig(lr=lr, weight_decay=weight_decay,
                                clip_norm=1.0,
                                warmup_steps=max(1, steps // 20),
                                decay_steps=steps)
        opt = adamw.init_state(cfg, params)
        Zj, Yj, Mj = (jnp.asarray(a, jnp.float32) for a in (Zp, Yp, Mp))
        loss = jnp.zeros(())
        for _ in range(max(1, steps)):
            params, opt, loss = _train_step(cfg, params, opt, Zj, Yj, Mj)
        w = np.asarray(params["w"], np.float64)
        bias = np.asarray(params["bias"], np.float64)
        resid = (Z @ w.T + bias - Y) * M
        sigma = math.sqrt(float((resid ** 2).sum()) / max(float(M.sum()), 1.0))
        return cls(candidates=candidates, w=w, bias=bias, mean=mean,
                   std=std, sigma=sigma, confidence_floor=confidence_floor,
                   version=version, n_samples=n,
                   train_loss=float(loss))

    # -- persistence -------------------------------------------------------

    def to_dict(self) -> dict:
        return {
            "format_version": FORMAT_VERSION,
            "kind": ARTIFACT_KIND,
            "model_version": self.version,
            "feature_names": list(FEATURE_NAMES),
            "candidates": self.candidates,
            "w": self.w.tolist(),
            "bias": self.bias.tolist(),
            "mean": self.mean.tolist(),
            "std": self.std.tolist(),
            "sigma": self.sigma,
            "confidence_floor": self.confidence_floor,
            "n_samples": self.n_samples,
            "train_loss": self.train_loss,
        }

    def save(self, path: str) -> None:
        """Atomic write (tmp + rename), like the cache flush — a reader
        never sees a half-written artifact."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".model.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @classmethod
    def from_dict(cls, data: dict) -> "DispatchModel":
        if data.get("kind") != ARTIFACT_KIND:
            raise ValueError(f"not a {ARTIFACT_KIND} artifact: "
                             f"kind={data.get('kind')!r}")
        fv = int(data.get("format_version", -1))
        if fv > FORMAT_VERSION or fv < 1:
            raise ValueError(f"unsupported artifact format_version {fv} "
                             f"(this build reads <= {FORMAT_VERSION})")
        if list(data.get("feature_names", [])) != list(FEATURE_NAMES):
            raise ValueError("artifact feature set does not match this "
                             "build; retrain the model")
        return cls(candidates=list(data["candidates"]),
                   w=np.asarray(data["w"]),
                   bias=np.asarray(data["bias"]),
                   mean=np.asarray(data["mean"]),
                   std=np.asarray(data["std"]),
                   sigma=float(data["sigma"]),
                   confidence_floor=float(data.get("confidence_floor", 0.7)),
                   version=int(data.get("model_version", 1)),
                   n_samples=int(data.get("n_samples", 0)),
                   train_loss=data.get("train_loss"))

    @classmethod
    def load(cls, path: str) -> "DispatchModel":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def train_and_save(entries: dict, path: str,
                   **train_kw) -> Optional[DispatchModel]:
    """Offline (re)train from a cache snapshot and persist next to it.

    The artifact version is bumped past any existing artifact's, so
    dispatch's mtime-keyed loader AND version-aware consumers both see
    the retrain as a new model.  Returns the model, or None when the
    snapshot holds no timing vectors yet."""
    version = 1
    try:
        version = DispatchModel.load(path).version + 1
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        pass
    model = DispatchModel.train(samples_from_entries(entries),
                                version=version, **train_kw)
    if model is not None:
        model.save(path)
    return model
