"""Composable transformer stacks for all assigned architectures.

A model is a sequence of *groups*; each group is a repeated *pattern* of
sublayer kinds (attn / local_attn / cross_attn / rglru / ssd). Group
repeats are stacked and executed with lax.scan (fast compiles at 512
devices, optional per-unit remat). Decode threads a cache pytree shaped
like the params (stacked along the repeat dim).

Cache entries per kind:
  attn        k, v: (B, Smax, KVH, hd)           [seq dim model-sharded]
  mla         c: (B, Smax, r), kr: (B, Smax, rope)
  local_attn  ring k, v: (B, window, KVH, hd), pos: (B? -> (window,)) slots
  cross_attn  as attn + static enc_k, enc_v: (B, Senc, KVH, hd)
  rglru       h: (B, w), conv: (B, cw-1, w)
  ssd         h: (B, H, P, N), conv: (B, cw-1, conv_ch)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (dense, mlp, mlp_init, rmsnorm,
                                 rmsnorm_init)


def _dtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def _cdtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# sublayer init / apply
# ---------------------------------------------------------------------------

def sublayer_init(key, kind, cfg, *, use_moe=True, self_causal=True):
    dt = _dtype(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"norm1": rmsnorm_init(D, dt)}
    if kind in ("attn", "local_attn", "cross_attn"):
        p["mixer"] = (attn.mla_init(ks[0], cfg, dt) if cfg.mla
                      else attn.gqa_init(ks[0], cfg, dt))
        if kind == "cross_attn":
            p["normx"] = rmsnorm_init(D, dt)
            p["xattn"] = attn.gqa_init(ks[2], cfg, dt)
        p["norm2"] = rmsnorm_init(D, dt)
        p["ffn"] = (moe_mod.moe_init(ks[1], cfg, dt)
                    if (cfg.moe and use_moe) else mlp_init(ks[1], D, cfg.d_ff, dt))
    elif kind == "rglru":
        p["mixer"] = ssm.rglru_init(ks[0], cfg, dt)
        p["norm2"] = rmsnorm_init(D, dt)
        p["ffn"] = (moe_mod.moe_init(ks[1], cfg, dt)
                    if (cfg.moe and use_moe) else mlp_init(ks[1], D, cfg.d_ff, dt))
    elif kind == "ssd":
        p["mixer"] = ssm.ssd_init(ks[0], cfg, dt)
    else:
        raise ValueError(kind)
    return p


def _ffn_apply(p, x, cfg, use_moe):
    if cfg.moe and use_moe:
        return moe_mod.moe_block(p, x, cfg)
    return mlp(p, x, layout=cfg.layer_layout), jnp.float32(0)


def _seq_shard(x):
    """Sequence parallelism on the residual stream: (B, S, D) sharded
    (batch, model, -). The per-layer remat/scan-saved residual shrinks by
    the model-axis size; GSPMD inserts the all-gather/reduce-scatter pair
    around each mixer (Megatron-SP)."""
    return shd.constrain(x, shd.batch_axes() or None, "model", None)


def sublayer_apply(p, kind, x, pos, cfg, *, enc=None, use_moe=True,
                   causal=True, cache=None):
    """Full-sequence forward. Returns (x, aux, cache) — ``cache`` is the
    populated prefill cache when a (zeroed) cache pytree is passed, else
    None. Attention K/V written to the cache are recomputed projections of
    the same operands and get CSE'd with the forward's own."""
    aux = jnp.float32(0)
    x = _seq_shard(x)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "local_attn", "cross_attn"):
        window = cfg.local_window if kind == "local_attn" else 0
        if cfg.mla:
            y = attn.mla_forward(p["mixer"], h, pos, cfg)
        else:
            y = attn.gqa_forward(p["mixer"], h, pos, cfg, causal=causal,
                                 window=window)
        if cache is not None:
            cache = sublayer_prefill_cache(p, kind, h, pos, cfg, cache,
                                           enc=enc)
        x = x + y
        if kind == "cross_attn":
            hx = rmsnorm(p["normx"], x, cfg.norm_eps)
            x = x + attn.gqa_forward(p["xattn"], hx, pos, cfg,
                                     kv_override=enc)
        h2 = rmsnorm(p["norm2"], _seq_shard(x), cfg.norm_eps)
        y2, aux = _ffn_apply(p["ffn"], h2, cfg, use_moe)
        x = _seq_shard(x + y2)
    elif kind == "rglru":
        y, hstate, conv_tail = ssm.rglru_forward(p["mixer"], h, cfg)
        if cache is not None:
            cache = dict(cache, h=hstate,
                         conv=conv_tail.astype(cache["conv"].dtype))
        x = x + y
        h2 = rmsnorm(p["norm2"], _seq_shard(x), cfg.norm_eps)
        y2, aux = _ffn_apply(p["ffn"], h2, cfg, use_moe)
        x = _seq_shard(x + y2)
    elif kind == "ssd":
        y, hstate, conv_tail = ssm.ssd_forward(p["mixer"], h, cfg)
        if cache is not None:
            cache = dict(cache, h=hstate,
                         conv=conv_tail.astype(cache["conv"].dtype))
        x = _seq_shard(x + y)
    return x, aux, cache


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def sublayer_cache(kind, cfg, batch, smax, enc_len=0):
    """ShapeDtypeStruct pytree for one sublayer's cache."""
    dt = _cdtype(cfg)
    hd = cfg.resolved_head_dim
    KVH = cfg.num_kv_heads
    D = cfg.d_model
    f32 = jnp.float32
    sd = jax.ShapeDtypeStruct
    if kind == "attn" or kind == "cross_attn":
        if cfg.mla:
            c = {"c": sd((batch, smax, cfg.kv_lora_rank), dt),
                 "kr": sd((batch, smax, cfg.qk_rope_dim), dt)}
        else:
            c = {"k": sd((batch, smax, KVH, hd), dt),
                 "v": sd((batch, smax, KVH, hd), dt)}
        if kind == "cross_attn":
            c["enc_k"] = sd((batch, enc_len, KVH, hd), dt)
            c["enc_v"] = sd((batch, enc_len, KVH, hd), dt)
        return c
    if kind == "local_attn":
        w = cfg.local_window
        return {"k": sd((batch, w, KVH, hd), dt),
                "v": sd((batch, w, KVH, hd), dt),
                "slot_pos": sd((batch, w), jnp.int32)}
    if kind == "rglru":
        w = cfg.rnn_width or D
        return {"h": sd((batch, w), f32),
                "conv": sd((batch, cfg.conv_width - 1, w), dt)}
    if kind == "ssd":
        inner = cfg.ssm_expand * D
        H = inner // cfg.ssm_head_dim
        return {"h": sd((batch, H, cfg.ssm_head_dim, cfg.ssm_state), f32),
                "conv": sd((batch, cfg.conv_width - 1,
                            inner + 2 * cfg.ssm_state), dt)}
    raise ValueError(kind)


def zeros_like_sds(tree):
    return jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), tree)


# ---------------------------------------------------------------------------
# decode-step sublayer
# ---------------------------------------------------------------------------

def sublayer_decode(p, kind, x, cache, cache_len, cfg, *, use_moe=True):
    aux = jnp.float32(0)
    h = rmsnorm(p["norm1"], x, cfg.norm_eps)
    if kind in ("attn", "cross_attn"):
        if cfg.mla:
            y, c, kr = attn.mla_decode(p["mixer"], h, cache["c"],
                                       cache["kr"], cache_len, cfg)
            cache = dict(cache, c=c, kr=kr)
        else:
            y, ck, cv = attn.gqa_decode(p["mixer"], h, cache["k"],
                                        cache["v"], cache_len, cfg)
            cache = dict(cache, k=ck, v=cv)
        x = x + y
        if kind == "cross_attn":
            hx = rmsnorm(p["normx"], x, cfg.norm_eps)
            yx = _cross_decode(p["xattn"], hx, cache["enc_k"],
                               cache["enc_v"], cfg)
            x = x + yx
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y2, aux = _ffn_apply(p["ffn"], h2, cfg, use_moe)
        x = x + y2
    elif kind == "local_attn":
        y, cache = _local_ring_decode(p["mixer"], h, cache, cache_len, cfg)
        x = x + y
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y2, aux = _ffn_apply(p["ffn"], h2, cfg, use_moe)
        x = x + y2
    elif kind == "rglru":
        y, hs, conv = ssm.rglru_decode(p["mixer"], h, cache["h"],
                                       cache["conv"], cfg)
        cache = dict(cache, h=hs, conv=conv)
        x = x + y
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        y2, aux = _ffn_apply(p["ffn"], h2, cfg, use_moe)
        x = x + y2
    elif kind == "ssd":
        y, hs, conv = ssm.ssd_decode(p["mixer"], h, cache["h"],
                                     cache["conv"], cfg)
        cache = dict(cache, h=hs, conv=conv)
        x = x + y
    return x, cache, aux


def _cross_decode(p, x, enc_k, enc_v, cfg):
    """Single-token cross-attention against static encoder KV."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    q = dense(p["wq"], x)  # (B,1,H,hd), no rope on cross
    KVH = enc_k.shape[2]
    G = cfg.num_heads // KVH
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   enc_k.astype(jnp.float32)) * hd ** -0.5
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr, enc_v.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads, hd).astype(x.dtype)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"]["w"].astype(x.dtype))


def _local_ring_decode(p, x, cache, cache_len, cfg):
    """Sliding-window decode with a ring buffer of width ``local_window``."""
    from repro.models.layers import rope as rope_fn
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    W = cfg.local_window
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    q = rope_fn(dense(p["wq"], x), pos, cfg.rope_theta)
    k_new = rope_fn(dense(p["wk"], x), pos, cfg.rope_theta)
    v_new = dense(p["wv"], x)
    slot = cache_len % W
    onehot = (jnp.arange(W) == slot).astype(cache["k"].dtype)
    ck = cache["k"] * (1 - onehot)[None, :, None, None] + \
        k_new.astype(cache["k"].dtype) * onehot[None, :, None, None]
    cv = cache["v"] * (1 - onehot)[None, :, None, None] + \
        v_new.astype(cache["v"].dtype) * onehot[None, :, None, None]
    spos = cache["slot_pos"] * (1 - onehot[None].astype(jnp.int32)) + \
        cache_len * onehot[None].astype(jnp.int32)
    valid = (spos <= cache_len) & (spos > cache_len - W)
    KVH = ck.shape[2]
    G = cfg.num_heads // KVH
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) * hd ** -0.5
    s = jnp.where(valid[:, None, None], s, attn.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr, cv.astype(jnp.float32))
    out = out.reshape(B, 1, cfg.num_heads, hd).astype(x.dtype)
    y = jnp.einsum("bshd,hdo->bso", out, p["wo"]["w"].astype(x.dtype))
    return y, dict(cache, k=ck, v=cv, slot_pos=spos)


# ---------------------------------------------------------------------------
# prefill-time cache population
# ---------------------------------------------------------------------------

def sublayer_prefill_cache(p, kind, x_normed, pos, cfg, cache, enc=None):
    """Populate a zeroed cache from the full prompt (run alongside the
    full-sequence forward; x_normed is norm1(x) for this sublayer)."""
    from repro.models.layers import rope as rope_fn
    B, S = x_normed.shape[:2]
    if kind in ("attn", "cross_attn"):
        if cfg.mla:
            dkv = dense(p["mixer"]["w_dkv"], x_normed)
            c_kv = rmsnorm(p["mixer"]["kv_norm"],
                           dkv[..., :cfg.kv_lora_rank], cfg.norm_eps)
            kr = rope_fn(dkv[..., None, cfg.kv_lora_rank:], pos,
                         cfg.rope_theta)[..., 0, :]
            c_kv, kr = _maybe_cache_shard(cfg, c_kv, kr)
            cache = dict(cache,
                         c=_write_prefix(cache["c"], c_kv),
                         kr=_write_prefix(cache["kr"], kr))
        else:
            k = dense(p["mixer"]["wk"], x_normed)
            if cfg.pos_emb == "rope":
                k = rope_fn(k, pos, cfg.rope_theta)
            v = dense(p["mixer"]["wv"], x_normed)
            k, v = _maybe_cache_shard(cfg, k, v)
            cache = dict(cache, k=_write_prefix(cache["k"], k),
                         v=_write_prefix(cache["v"], v))
        if kind == "cross_attn" and enc is not None:
            cache = dict(cache,
                         enc_k=dense(p["xattn"]["wk"], enc).astype(cache["enc_k"].dtype),
                         enc_v=dense(p["xattn"]["wv"], enc).astype(cache["enc_v"].dtype))
    elif kind == "local_attn":
        W = cfg.local_window
        k = rope_fn(dense(p["mixer"]["wk"], x_normed), pos, cfg.rope_theta)
        v = dense(p["mixer"]["wv"], x_normed)
        take = min(W, S)
        sl = slice(S - take, S)
        slots = (pos[0, sl] % W)
        ck = jnp.zeros_like(cache["k"]).at[:, slots].set(
            k[:, sl].astype(cache["k"].dtype))
        cv = jnp.zeros_like(cache["v"]).at[:, slots].set(
            v[:, sl].astype(cache["v"].dtype))
        sp = jnp.full_like(cache["slot_pos"], -10**9).at[:, slots].set(
            jnp.broadcast_to(pos[0, sl], (B, take)))
        cache = dict(cache, k=ck, v=cv, slot_pos=sp)
    return cache


def _write_prefix(buf, val):
    return buf.at[:, :val.shape[1]].set(val.astype(buf.dtype))


def _maybe_cache_shard(cfg, *tensors):
    """Hillclimb (prefill_cache_seqshard): pin freshly computed K/V (or
    c_kv/k_rope) to the cache's (batch, seq->model) layout before the
    dynamic-update write, so GSPMD doesn't fall back to the involuntary
    full-rematerialization reshard inside the layer scan."""
    if not cfg.prefill_cache_seqshard:
        return tensors
    ba = shd.batch_axes() or None
    out = tuple(
        shd.constrain(t, ba, "model", *([None] * (t.ndim - 2)))
        for t in tensors)
    return out
