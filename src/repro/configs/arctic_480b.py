"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

bf16 optimizer moments: fp32 m/v do not fit 16 GB/chip at 256 chips
(480e9 × (4+4+4+2) / 256 = 26 GB); bf16 params+m+v = 11.3 GB (see
EXPERIMENTS.md §Dry-run)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, num_kv_heads=8,
    d_ff=4864, vocab_size=32000,
    moe=True, num_experts=128, top_k=2, moe_d_ff=4864,
    dense_residual=True,
    fsdp=True, remat="block",
    param_dtype="bfloat16", opt_state_dtype="bfloat16",
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="arctic-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=384,
        num_experts=8, top_k=2, moe_d_ff=96, fsdp=False, remat="none",
        param_dtype="float32", opt_state_dtype="float32",
        moe_dispatch="einsum")
