"""Architecture configs. ``get_config(name)`` resolves any assigned arch id."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, get_config, list_configs  # noqa: F401
