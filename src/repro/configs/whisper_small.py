"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified]. 12 encoder + 12 decoder layers; the conv
frontend is a stub: input_specs supplies (B, 1500, d_model) frame
embeddings. Decoder layers: self-attn + cross-attn + MLP."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865,
    group_pattern=("cross_attn",), encoder_layers=12,
    num_frontend_tokens=1500, pos_emb="sinusoid",
    remat="block",
)


def smoke():
    return dataclasses.replace(
        CONFIG, remat="none", name="whisper-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=160, vocab_size=384,
        encoder_layers=2, num_frontend_tokens=20)
