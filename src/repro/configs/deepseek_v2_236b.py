"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]. First layer dense FFN (d_ff=12288)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    moe=True, num_experts=160, top_k=6, moe_d_ff=1536,
    num_shared_experts=2, first_k_dense=1,
    mla=True, kv_lora_rank=512, q_lora_rank=1536,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    fsdp=True, remat="block", opt_state_dtype="bfloat16",
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="deepseek-smoke", num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=384,
        num_experts=8, top_k=2, moe_d_ff=48, num_shared_experts=1,
        first_k_dense=1, kv_lora_rank=32, q_lora_rank=48,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        fsdp=False, remat="none", opt_state_dtype="float32",
        moe_dispatch="einsum")
