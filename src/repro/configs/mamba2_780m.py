"""mamba2-780m [ssm] — SSD (state-space duality) [arXiv:2405.21060;
unverified]. Attention-free; runs long_500k."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    group_pattern=("ssd",), ssm_state=128, ssm_expand=2,
    ssm_head_dim=64, ssm_chunk=256,
    remat="block",
)


def smoke():
    return dataclasses.replace(
        CONFIG, remat="none", name="mamba2-smoke", num_layers=2, d_model=64,
        vocab_size=384, ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
