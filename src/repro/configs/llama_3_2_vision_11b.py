"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

Backbone only: every 5th layer cross-attends precomputed patch embeddings
(the vision-tower frontend is a stub supplying (B, 1601, d_model))."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    group_pattern=("cross_attn", "attn", "attn", "attn", "attn"),
    num_frontend_tokens=1601, fsdp=True, remat="block",
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="vision-smoke", num_layers=10, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=160, vocab_size=384,
        num_frontend_tokens=17, fsdp=False, remat="none")
