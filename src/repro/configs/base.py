"""Config system: model + shape + run configs.

Every assigned architecture has a module ``repro/configs/<id>.py`` exposing
``CONFIG: ModelConfig`` (exact paper/hf numbers) and ``smoke()`` (a reduced
same-family config for CPU tests). ``get_config`` resolves ids with either
dashes or underscores.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# Layer kinds usable in group patterns:
#   attn        self-attention + MLP (pre-norm residual block)
#   local_attn  sliding-window self-attention + MLP
#   cross_attn  self-attention + cross-attention + MLP
#   rglru       RG-LRU recurrent block + MLP
#   ssd         Mamba-2 SSD block (standalone, no MLP)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    pos_emb: str = "rope"          # rope | sinusoid (whisper)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    group_pattern: Tuple[str, ...] = ("attn",)
    tail_pattern: Tuple[str, ...] = ()
    local_window: int = 0
    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    dense_residual: bool = False   # arctic: dense MLP in parallel with MoE
    first_k_dense: int = 0         # deepseek: first layer uses dense FFN
    capacity_factor: float = 1.25
    moe_dispatch: str = "zipper"   # zipper (shard_map sort+all_to_all) | einsum
    # --- MLA (DeepSeek-V2) ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM (Mamba-2) / RG-LRU ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    rnn_width: int = 0             # RG-LRU recurrence width (0 -> d_model)
    # --- enc-dec / VLM / audio stubs ---
    encoder_layers: int = 0        # whisper encoder depth
    num_frontend_tokens: int = 0   # stub frame/patch embedding count
    # --- numerics & memory policy ---
    dtype: str = "bfloat16"        # activation/compute dtype
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    remat: str = "none"            # none | block
    fsdp: bool = False
    # --- attention impl: xla (blocked online-softmax) | naive ---
    attn_impl: str = "xla"
    attn_q_block: int = 2048
    attn_kv_block: int = 1024
    # causal-block skipping (hillclimb: halves attention FLOPs)
    attn_block_skip: bool = False
    # --- hillclimb knobs (default off = paper-faithful/initial baseline) ---
    # intra-layer layout: "tp" (Megatron: heads/d_ff sharded over model,
    # activations all-gathered per layer) or "sp" (tokens stay sharded over
    # the model axis; per-layer *weights* are gathered instead — wins when
    # weights_per_layer << activations_per_layer)
    layer_layout: str = "tp"
    # carry softmax probabilities in bf16 between the two attention
    # matmuls (flash-attention-2 numerics; halves the dominant
    # score-chain traffic)
    attn_p_bf16: bool = False
    # decode cache update: one-hot multiply (baseline; touches the whole
    # cache) vs dynamic-update-slice via scatter (touches one slot)
    decode_dus: bool = False
    # chunked vocab head + cross-entropy: avoids materializing the full
    # (B, S, V) f32 logits block (memory term)
    ce_chunk: int = 0
    # constrain prefill KV-cache writes to the cache's (seq -> model)
    # sharding, killing the involuntary-rematerialization reshard GSPMD
    # otherwise inserts per layer (collective term)
    prefill_cache_seqshard: bool = False
    # fully unroll layer scans (used by the dry-run cost extrapolation:
    # XLA cost_analysis counts while-loop bodies once, so roofline terms
    # are measured on unrolled 1- and 2-rep variants and extrapolated)
    scan_unroll: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def groups(self):
        """((pattern, repeats), ...) covering num_layers exactly
        (excluding the first_k_dense unscanned lead units)."""
        n = len(self.group_pattern)
        body = self.num_layers - len(self.tail_pattern) - self.first_k_dense
        assert body % n == 0, (self.name, body, n)
        out = [(self.group_pattern, body // n)]
        if self.tail_pattern:
            out.append((self.tail_pattern, 1))
        return tuple(out)

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        D, V = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        n = 2 * V * D  # embed + head
        kinds = [k for pat, rep in self.groups for k in pat * rep]
        for kind in kinds:
            if kind in ("attn", "local_attn", "cross_attn"):
                if self.mla:
                    r, qr = self.kv_lora_rank, self.q_lora_rank
                    qk = self.qk_nope_dim + self.qk_rope_dim
                    n += D * (r + self.qk_rope_dim) + D * qr
                    n += qr * self.num_heads * qk
                    n += r * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                    n += self.num_heads * self.v_head_dim * D
                else:
                    n += D * self.num_heads * hd * 2  # q, o
                    n += D * self.num_kv_heads * hd * 2  # k, v
                if kind == "cross_attn":
                    n += D * self.num_heads * hd * 2 + D * self.num_kv_heads * hd * 2
            if kind == "ssd":
                inner = self.ssm_expand * D
                n += D * (2 * inner + 2 * self.ssm_state +
                          inner // self.ssm_head_dim) + inner * D
                continue
            if kind == "rglru":
                w = self.rnn_width or D
                n += D * w * 2 + w * D  # branch in-projections + out
                n += 2 * w * w // w * 0 + 4 * w  # diagonal gates + conv-ish
            # FFN
            if self.moe:
                f = self.moe_d_ff
                n += D * f * 3 * self.num_experts
                n += D * self.num_experts  # router
                if self.num_shared_experts:
                    n += D * f * 3 * self.num_shared_experts
                if self.dense_residual:
                    n += D * self.d_ff * 3
            elif kind != "ssd":
                n += D * self.d_ff * 3
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        D, f = self.d_model, self.moe_d_ff
        kinds = [k for pat, rep in self.groups for k in pat * rep]
        n_moe_layers = sum(1 for k in kinds if k != "ssd") - self.first_k_dense
        inactive = n_moe_layers * D * f * 3 * (self.num_experts - self.top_k)
        return int(full - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "tinyllama_1_1b", "phi4_mini_3_8b", "qwen1_5_0_5b", "granite_3_2b",
    "llama_3_2_vision_11b", "recurrentgemma_9b", "arctic_480b",
    "deepseek_v2_236b", "mamba2_780m", "whisper_small",
]

# archs whose every layer is full quadratic attention: long_500k skipped
FULL_ATTENTION_ARCHS = {
    "tinyllama_1_1b", "phi4_mini_3_8b", "qwen1_5_0_5b", "granite_3_2b",
    "llama_3_2_vision_11b", "arctic_480b", "deepseek_v2_236b",
    "whisper_small",
}


def norm_id(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{norm_id(name)}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{norm_id(name)}")
    return mod.smoke()


def list_configs():
    return list(ARCH_IDS)


def cells():
    """All assigned (arch, shape) cells, with documented skips applied."""
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            if s == "long_500k" and a in FULL_ATTENTION_ARCHS:
                continue  # O(S^2) attention at 524288 — documented skip
            out.append((a, s))
    return out
