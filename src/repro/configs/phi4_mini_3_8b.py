"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA [arXiv:2412.08905; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=8192, vocab_size=200064, rope_theta=10000.0,
    remat="block",
)


def smoke():
    return dataclasses.replace(
        CONFIG, remat="none", name="phi4-mini-smoke", num_layers=2, d_model=96,
        num_heads=6, num_kv_heads=2, d_ff=256, vocab_size=512)
