"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2
[arXiv:2402.19427; unverified]. 38 layers = 12×(rec,rec,attn) + (rec,rec)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    group_pattern=("rglru", "rglru", "local_attn"),
    tail_pattern=("rglru", "rglru"),
    local_window=2048, rnn_width=4096, fsdp=True, remat="block",
)


def smoke():
    return dataclasses.replace(
        CONFIG, name="recurrentgemma-smoke", num_layers=5, d_model=64,
        num_heads=4, num_kv_heads=1, head_dim=16, d_ff=160, vocab_size=384,
        tail_pattern=("rglru", "rglru"), local_window=32, rnn_width=64,
        fsdp=False, remat="none")
