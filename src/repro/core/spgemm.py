"""Row-wise (Gustavson) SpGEMM engines — the paper's §V-B implementations.

Five implementations, mirroring the paper's evaluation:

  scl-array  — scalar row loop with a dense accumulator row (Gilbert et al.)
  scl-hash   — scalar row loop with a hash-style unique/accumulate
  esc        — vectorized Expand-Sort-Compress (the vec-radix analogue);
               fully jittable with static capacities (XLA sort plays the
               radix sort's role)
  spz        — merge-based SpGEMM on the SparseZipper primitives: chunked
               stream sort + zip-merge tree with data-dependent advancement,
               lock-step groups of S streams.  Two drivers: the default
               device-resident "fused" pipeline (expand + sort + full merge
               tree under one jit, chunk pointers as jax.lax.while_loop
               state) and the original "host" lock-step Python driver (one
               kernel issue per chunk — the stats-faithful Fig. 9-11 path)
  spz-rsort  — spz with row indices pre-sorted by per-row work to reduce
               lock-step imbalance (paper §V-B / Fig. 9)

All produce identical CSR outputs (property-tested against scl-array).
``spz`` returns dynamic-instruction statistics (mssort/mszip counts) used by
the Fig. 10/11 benchmark analogues.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.formats import CSR, EMPTY, csr_from_coo, csr_to_numpy, row_ids_from_indptr
from repro.core import stream as kvstream
from repro.kernels import backend as kb


# ---------------------------------------------------------------------------
# work statistics (Table III)
# ---------------------------------------------------------------------------

def row_work(A: CSR, B: CSR) -> np.ndarray:
    """#multiplications to compute each output row (Table III 'Work')."""
    a_indptr, a_idx, _ = csr_to_numpy(A)
    b_indptr = np.asarray(B.indptr)
    blen = (b_indptr[1:] - b_indptr[:-1]).astype(np.int64)
    w = np.zeros(A.n_rows, np.int64)
    contrib = blen[a_idx]
    rows = np.repeat(np.arange(A.n_rows), a_indptr[1:] - a_indptr[:-1])
    np.add.at(w, rows, contrib)
    return w


def work_stats(A: CSR, B: CSR, group: int = 16) -> dict:
    """Per-row and per-group work stats (Table III reproduction)."""
    w = row_work(A, B)
    n = len(w)
    pad = (-n) % group
    wg = np.pad(w, (0, pad)).reshape(-1, group).sum(1)
    return {
        "nnz": int(np.asarray(A.indptr)[-1]),
        "density": float(np.asarray(A.indptr)[-1]) / (A.n_rows * A.n_cols),
        "avg_work_per_row": float(w.mean()),
        "avg_work_per_group": float(wg.mean()),
        "work_var_per_group": float(wg.std() / max(wg.mean(), 1e-12)),
        "total_work": int(w.sum()),
    }


# ---------------------------------------------------------------------------
# scalar baselines (numpy, row-at-a-time — the paper's scl-*)
# ---------------------------------------------------------------------------

def spgemm_scl_array(A: CSR, B: CSR) -> CSR:
    """Dense-accumulator-row scalar SpGEMM (oracle for everything else)."""
    a_indptr, a_idx, a_val = csr_to_numpy(A)
    b_indptr, b_idx, b_val = csr_to_numpy(B)
    acc = np.zeros(B.n_cols, np.float64)
    out_r, out_c, out_v = [], [], []
    for i in range(A.n_rows):
        touched = []
        for t in range(a_indptr[i], a_indptr[i + 1]):
            j, av = a_idx[t], a_val[t]
            s, e = b_indptr[j], b_indptr[j + 1]
            cols = b_idx[s:e]
            acc[cols] += av * b_val[s:e]
            touched.append(cols)
        if touched:
            cols = np.unique(np.concatenate(touched))
            vals = acc[cols]
            acc[cols] = 0.0
            nz = vals != 0.0
            out_r.append(np.full(nz.sum(), i, np.int64))
            out_c.append(cols[nz])
            out_v.append(vals[nz])
    if not out_r:
        return csr_from_coo([], [], [], (A.n_rows, B.n_cols))
    return csr_from_coo(np.concatenate(out_r), np.concatenate(out_c),
                        np.concatenate(out_v), (A.n_rows, B.n_cols))


def spgemm_scl_hash(A: CSR, B: CSR) -> CSR:
    """Hash-accumulate scalar SpGEMM (paper's scl-hash; here the per-row
    hash table is modelled by sort-unique accumulation over the expanded
    products of one row at a time, then a final sort — same asymptotics,
    no O(n_cols) state)."""
    a_indptr, a_idx, a_val = csr_to_numpy(A)
    b_indptr, b_idx, b_val = csr_to_numpy(B)
    out_r, out_c, out_v = [], [], []
    for i in range(A.n_rows):
        ks, vs = [], []
        for t in range(a_indptr[i], a_indptr[i + 1]):
            j, av = a_idx[t], a_val[t]
            s, e = b_indptr[j], b_indptr[j + 1]
            ks.append(b_idx[s:e])
            vs.append(av * b_val[s:e])
        if not ks:
            continue
        k = np.concatenate(ks)
        v = np.concatenate(vs)
        uk, inv = np.unique(k, return_inverse=True)
        uv = np.zeros(len(uk), np.float64)
        np.add.at(uv, inv, v)
        nz = uv != 0.0
        out_r.append(np.full(nz.sum(), i, np.int64))
        out_c.append(uk[nz])
        out_v.append(uv[nz])
    if not out_r:
        return csr_from_coo([], [], [], (A.n_rows, B.n_cols))
    return csr_from_coo(np.concatenate(out_r), np.concatenate(out_c),
                        np.concatenate(out_v), (A.n_rows, B.n_cols))


# ---------------------------------------------------------------------------
# ESC (vec-radix analogue) — fully jittable with static capacities
# ---------------------------------------------------------------------------

def esc_core_impl(a_indptr, a_idx, a_val, b_indptr, b_idx, b_val,
                   cap_products: int, n_rows: int, n_cols: int):
    nnz_a_cap = a_idx.shape[0]
    # --- expansion: product p belongs to A-entry t = searchsorted(Wcum, p)
    a_rows = row_ids_from_indptr(a_indptr, nnz_a_cap)
    blen = b_indptr[1:] - b_indptr[:-1]
    nnz_a = a_indptr[-1]
    t_valid = jnp.arange(nnz_a_cap) < nnz_a
    j_of_t = jnp.where(t_valid, a_idx, 0)
    w_t = jnp.where(t_valid, blen[j_of_t], 0)
    wcum = jnp.cumsum(w_t)
    total_work = wcum[-1]
    p = jnp.arange(cap_products, dtype=jnp.int32)
    t_of_p = jnp.searchsorted(wcum, p, side="right").astype(jnp.int32)
    t_of_p = jnp.clip(t_of_p, 0, nnz_a_cap - 1)
    p_valid = p < total_work
    base = jnp.where(t_of_p > 0, wcum[t_of_p - 1], 0)
    s_of_p = b_indptr[j_of_t[t_of_p]] + (p - base)
    s_of_p = jnp.clip(s_of_p, 0, b_idx.shape[0] - 1)
    prod_row = jnp.where(p_valid, a_rows[t_of_p], n_rows)
    prod_col = jnp.where(p_valid, b_idx[s_of_p], n_cols)
    prod_val = jnp.where(p_valid, a_val[t_of_p] * b_val[s_of_p], 0.0)
    # --- sort by (row, col): two stable passes (the radix-sort analogue)
    o1 = jnp.argsort(prod_col, stable=True)
    r1, c1, v1 = prod_row[o1], prod_col[o1], prod_val[o1]
    o2 = jnp.argsort(r1, stable=True)
    r2, c2, v2 = r1[o2], c1[o2], v1[o2]
    # --- compress: accumulate duplicate (row, col)
    first = (r2 != jnp.roll(r2, 1)) | (c2 != jnp.roll(c2, 1))
    first = first.at[0].set(True)
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1
    out_v = jax.ops.segment_sum(v2, seg, num_segments=cap_products)
    pos = seg
    out_r = jnp.full(cap_products, n_rows, jnp.int32).at[pos].set(r2.astype(jnp.int32))
    out_c = jnp.full(cap_products, n_cols, jnp.int32).at[pos].set(c2.astype(jnp.int32))
    valid_out = (out_r < n_rows) & (out_v != 0.0)
    n_out = jnp.sum(valid_out, dtype=jnp.int32)
    return out_r, out_c, out_v, valid_out, n_out


# jitted single-matrix entry; the unjitted esc_core_impl is vmapped by the
# batched dispatch path (core/dispatch.py) so a whole batch shares one jit
_esc_core = functools.partial(
    jax.jit, static_argnames=("cap_products", "n_rows", "n_cols"))(esc_core_impl)


def spgemm_esc(A: CSR, B: CSR, cap_products: int | None = None) -> CSR:
    """Vectorized Expand-Sort-Compress SpGEMM (the vec-radix analogue)."""
    if cap_products is None:
        cap_products = int(max(16, row_work(A, B).sum()))
    r, c, v, valid, _ = _esc_core(A.indptr, A.indices, A.data,
                                  B.indptr, B.indices, B.data,
                                  cap_products, A.n_rows, B.n_cols)
    r, c, v, valid = map(np.asarray, (r, c, v, valid))
    return csr_from_coo(r[valid], c[valid], v[valid], (A.n_rows, B.n_cols))


# ---------------------------------------------------------------------------
# SparseZipper merge-based SpGEMM (spz / spz-rsort)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SpzStats:
    """Dynamic instruction counts (Fig. 11), traffic (Fig. 10) and the
    execution-time breakdown (Fig. 9)."""
    n_mssort: int = 0        # sort-instruction issues (S-stream lock-step)
    n_mszip: int = 0         # zip-instruction issues
    sort_elems: int = 0      # key-value tuples moved through sort
    zip_elems: int = 0       # key-value tuples moved through merge
    chunk_loads: int = 0     # mlxe.t analogue (chunk fronts built)
    chunk_stores: int = 0    # msxe.t analogue
    t_preprocess: float = 0.0  # row-work calc (+ rsort row ordering)
    t_expand: float = 0.0      # stream expansion (multiplications)
    t_sort: float = 0.0        # stream sorting + merging
    t_output: float = 0.0      # output generation / row reordering


def expand_group(rows, a_indptr, a_idx, a_val, b_indptr, b_idx, b_val):
    """Vectorized expansion (RVV phase in the paper) for a group of rows.
    Returns per-row (cols, vals) numpy arrays of partial products."""
    out = []
    for i in rows:
        s, e = a_indptr[i], a_indptr[i + 1]
        js = a_idx[s:e]
        avs = a_val[s:e]
        if len(js) == 0:
            out.append((np.empty(0, np.int32), np.empty(0, np.float32)))
            continue
        starts = b_indptr[js]
        lens = (b_indptr[js + 1] - starts).astype(np.int64)
        total = int(lens.sum())
        if total == 0:
            out.append((np.empty(0, np.int32), np.empty(0, np.float32)))
            continue
        pos = np.arange(total) - np.repeat(np.cumsum(lens) - lens, lens) \
            + np.repeat(starts, lens)
        cols = b_idx[pos].astype(np.int32)
        vals = (np.repeat(avs, lens) * b_val[pos]).astype(np.float32)
        out.append((cols, vals))
    return out


def sort_phase(products, R, S, backend, stats: SpzStats, cap_s=None):
    """Chunk-sort every stream's products into sorted unique partitions.

    Returns a list of partitions; partition p = (keys (S, R), vals (S, R),
    lens (S,)) — the sorted-unique output of chunk p across all lock-step
    streams (lens[s] == 0 where stream s has no p-th chunk)."""
    plens = np.array([len(k) for k, _ in products], np.int64)
    max_len = int(plens.max()) if S else 0
    n_chunks = max(1, -(-max_len // R)) if max_len else 0
    # pad the ragged product lists into one (S, n_chunks*R) buffer
    K = np.full((S, n_chunks * R), EMPTY, np.int32)
    V = np.zeros((S, n_chunks * R), np.float32)
    for s, (k, v) in enumerate(products):
        K[s, :len(k)] = k
        V[s, :len(k)] = v
    parts = []
    for c in range(n_chunks):
        lens = np.clip(plens - c * R, 0, R).astype(np.int32)
        if not lens.any():
            break
        keys = K[:, c * R:(c + 1) * R]
        vals = V[:, c * R:(c + 1) * R]
        ok, ov, ol = kvstream.sort_chunks(keys, vals, lens, backend=backend,
                                          cap_s=cap_s)
        stats.n_mssort += 1
        stats.sort_elems += int(lens.sum())
        stats.chunk_loads += 1
        stats.chunk_stores += 1
        parts.append((np.asarray(ok), np.asarray(ov),
                      np.asarray(ol).astype(np.int64)))
    return parts


def _take_chunk(K, V, lens, ptr, R):
    """Vectorized chunk front: rows [ptr, min(ptr+R, lens)) of each stream.
    K/V: (S, L) padded; returns (keys (S,R), vals (S,R), n (S,))."""
    S, L = K.shape
    idx = ptr[:, None] + np.arange(R)[None, :]
    ok = idx < lens[:, None]
    idx_c = np.minimum(idx, max(L - 1, 0))
    keys = np.where(ok, np.take_along_axis(K, idx_c, 1), EMPTY).astype(np.int32)
    vals = np.where(ok, np.take_along_axis(V, idx_c, 1), 0.0).astype(np.float32)
    return keys, vals, ok.sum(1).astype(np.int32)


def _put_rows(K, V, optr, src_k, src_v, n):
    """Vectorized append: write src[s, :n[s]] at K[s, optr[s]:...].
    Masked fancy indexing — invalid lanes are simply not written (a clamp
    here would let a masked write collide with the last valid slot)."""
    W = src_k.shape[1]
    idx = optr[:, None] + np.arange(W)[None, :]
    ok = np.arange(W)[None, :] < n[:, None]
    rows, _ = np.nonzero(ok)
    K[rows, idx[ok]] = src_k[ok]
    V[rows, idx[ok]] = src_v[ok]


def merge_round(A, B, R, backend, stats: SpzStats, cap_s=None):
    """Merge partition pair lock-step across streams, chunk by chunk.
    A, B: (keys (S, La), vals, lens (S,)) padded partitions.
    Returns merged (keys (S, La+Lb), vals, lens)."""
    (Ka, Va, lensA), (Kb, Vb, lensB) = A, B
    S = Ka.shape[0]
    Lo = Ka.shape[1] + Kb.shape[1]
    Ko = np.full((S, Lo), EMPTY, np.int32)
    Vo = np.zeros((S, Lo), np.float32)
    pa = np.zeros(S, np.int64)
    pb = np.zeros(S, np.int64)
    optr = np.zeros(S, np.int64)
    while True:
        # only streams with BOTH sides unexhausted participate (the driver
        # copy-through below handles the rest)
        both = (pa < lensA) & (pb < lensB)
        if not both.any():
            break
        ka, va, la = _take_chunk(Ka, Va, np.where(both, lensA, 0), pa, R)
        kb_, vb, lb = _take_chunk(Kb, Vb, np.where(both, lensB, 0), pb, R)
        res = kvstream.merge_chunks(ka, va, la, kb_, vb, lb, backend=backend,
                                    cap_s=cap_s)
        klo, vlo, khi, vhi, ca, cb, ol = map(np.asarray, res)
        stats.n_mszip += 1
        stats.zip_elems += int(la.sum() + lb.sum())
        stats.chunk_loads += 2
        stats.chunk_stores += 1
        merged_k = np.concatenate([klo, khi], 1)
        merged_v = np.concatenate([vlo, vhi], 1)
        _put_rows(Ko, Vo, optr, merged_k, merged_v, ol.astype(np.int64))
        optr += ol
        pa += ca
        pb += cb
    # copy-through tails (one side exhausted)
    for (K, V, lens, ptr) in ((Ka, Va, lensA, pa), (Kb, Vb, lensB, pb)):
        rem = (lens - ptr).clip(0)
        W = int(rem.max()) if len(rem) else 0
        if W > 0:
            idx = np.minimum(ptr[:, None] + np.arange(W)[None, :],
                             K.shape[1] - 1)
            ok = np.arange(W)[None, :] < rem[:, None]
            src_k = np.where(ok, np.take_along_axis(K, idx, 1), EMPTY)
            src_v = np.where(ok, np.take_along_axis(V, idx, 1), 0.0)
            _put_rows(Ko, Vo, optr, src_k.astype(np.int32),
                      src_v.astype(np.float32), rem)
            optr += rem
            stats.chunk_stores += int((-(-rem // R)).max())
    return Ko, Vo, optr.astype(np.int64)


def merge_tree_host(parts, R, backend, stats: SpzStats, cap_s=None):
    """Zip-merge tree: halve partition count per round, lock-step.
    Returns the single surviving partition (keys, vals, lens) or None."""
    while len(parts) > 1:
        nxt = []
        for j in range(0, len(parts) - 1, 2):
            nxt.append(merge_round(parts[j], parts[j + 1], R, backend,
                                    stats, cap_s=cap_s))
        if len(parts) % 2:
            nxt.append(parts[-1])
        parts = nxt
    return parts[0] if parts else None


# ---------------------------------------------------------------------------
# device-resident (fused) spz pipeline
# ---------------------------------------------------------------------------

def _fused_expand(row_ids, lane_ids, a_indptr, a_idx, a_val,
                  b_indptr, b_idx, b_val, L: int):
    """Device-side expansion: per-stream padded partial products.

    row_ids/lane_ids: (S,) int32 — stream s expands output row
    ``row_ids[s]`` of batch lane ``lane_ids[s]`` (row_ids < 0 marks
    padding streams).  Matrix arrays are (batch, ...) stacked.  Returns
    (keys (S, L), vals (S, L), plens (S,)) with EMPTY/0 padding — the
    device replacement for the host ``expand_group`` + chunk-buffer
    marshaling.
    """
    Bn, n_rows1 = a_indptr.shape
    nnz_cap = a_idx.shape[1]
    bcap = b_idx.shape[1]
    valid_s = row_ids >= 0
    lane = jnp.clip(lane_ids.astype(jnp.int32), 0, Bn - 1)
    row = jnp.clip(row_ids.astype(jnp.int32), 0, n_rows1 - 2)
    # per-lane work geometry: w[t] = |B row a_idx[t]| for valid entries
    blen = (b_indptr[:, 1:] - b_indptr[:, :-1]).astype(jnp.int32)
    nnz = a_indptr[:, -1]
    t_ok = jnp.arange(nnz_cap, dtype=jnp.int32)[None, :] < nnz[:, None]
    j_all = jnp.where(t_ok, a_idx, 0)
    w = jnp.where(t_ok, jnp.take_along_axis(blen, j_all, axis=1), 0)
    wcum0 = jnp.concatenate(
        [jnp.zeros((Bn, 1), jnp.int32), jnp.cumsum(w, axis=1)], axis=1)
    # flatten lanes onto one monotone axis so one searchsorted serves the
    # whole batch: lane l lives at offset l * (max total work + 1)
    M = jnp.max(wcum0[:, -1]) + 1
    offs = jnp.arange(Bn, dtype=jnp.int32) * M
    wflat = (wcum0 + offs[:, None]).reshape(-1)
    t0 = a_indptr[lane, row]
    t1 = a_indptr[lane, row + 1]
    ws = wcum0[lane, t0]
    we = jnp.where(valid_s, wcum0[lane, t1], ws)
    plens = (we - ws).astype(jnp.int32)
    p = jnp.arange(L, dtype=jnp.int32)
    pvalid = p[None, :] < plens[:, None]
    g = jnp.where(pvalid, ws[:, None] + p[None, :], ws[:, None])
    q = (g + offs[lane][:, None]).reshape(-1)
    # product g belongs to the last A-entry whose cumulated work <= g
    tg = jnp.searchsorted(wflat, q, side="right").reshape(g.shape) - 1
    t = jnp.clip(tg - (lane * (nnz_cap + 1))[:, None], 0, nnz_cap - 1)
    base = wflat[tg] - offs[lane][:, None]
    j = a_idx[lane[:, None], t]
    pos = jnp.clip(b_indptr[lane[:, None], j] + (g - base), 0, bcap - 1)
    keys = jnp.where(pvalid, b_idx[lane[:, None], pos], EMPTY)
    vals = jnp.where(pvalid,
                     a_val[lane[:, None], t] * b_val[lane[:, None], pos], 0.0)
    return keys, vals.astype(jnp.float32), plens


def _fused_bucket_impl(row_ids, lane_ids, a_indptr, a_idx, a_val,
                       b_indptr, b_idx, b_val, R: int, L: int,
                       backend: str):
    """One work bucket of a lock-step group, fully device-resident:
    expansion, chunk sort, and the whole zip-merge tree chained under a
    single trace.  Returns (keys (N, L), vals, lens (N,), rounds) where
    rounds carries the per-(round, pair) merge counters (see
    kernels/merge_tree.py zip_merge_tree detailed mode)."""
    keys, vals, plens = _fused_expand(row_ids, lane_ids, a_indptr, a_idx,
                                      a_val, b_indptr, b_idx, b_val, L)
    return kvstream.fused_sort_merge(keys, vals, plens, R=R,
                                     backend=backend, detailed=True)


# one compiled pipeline per static (N, L, R) bucket + matrix capacity
_fused_bucket = functools.partial(
    jax.jit, static_argnames=("R", "L", "backend"))(_fused_bucket_impl)


def _pow2_chunks(max_plen: int, R: int) -> int:
    """Partition count for the merge tree: next pow2 >= ceil(max_plen/R)."""
    q = -(-int(max_plen) // R)
    return 1 << max(0, q - 1).bit_length()


def fused_process_group(items, plens, mats, R, backend, stats: SpzStats,
                         out_k: dict | None = None,
                         out_v: dict | None = None,
                         coo: list | None = None) -> None:
    """Run one lock-step group of work items through the fused pipeline.

    items: [(lane, row)] output rows of the group; plens: per-item
    product counts; mats: six (batch, ...) stacked CSR arrays; results
    land in out_k/out_v keyed by (lane, row), or — when ``coo`` is given
    instead — as vectorized (rows, cols, vals) triples appended to it
    (the single-matrix fast path: no per-row slicing).

    Streams are bucketed by their own pow2 chunk count so a skewed group
    does not pad every stream to the group-max width (the fused analogue
    of the lock-step imbalance rsort targets).  The payload per stream is
    independent of which streams share a kernel, so bucketing cannot
    change results; the lock-step *instruction counts* are group-wide, so
    they are rebuilt exactly from the per-(round, pair) bucket counters —
    a pair's issue count is the max per-stream step count (elementwise
    max over buckets), zip_elems a plain sum.  Sort-phase counters depend
    only on plens and are computed here directly.  chunk_stores is
    approximate for this driver: the host tree passes odd partitions
    through for free, while the pow2 tree copies them through an empty
    merge."""
    empty_k = np.empty(0, np.int32)
    empty_v = np.empty(0, np.float32)
    buckets: dict[int, list[int]] = {}
    for ix, (it, pl) in enumerate(zip(items, plens)):
        if pl == 0:
            if coo is None:
                out_k[it] = empty_k
                out_v[it] = empty_v
        else:
            buckets.setdefault(_pow2_chunks(int(pl), R), []).append(ix)
    if not buckets:
        return
    max_plen = int(plens.max())
    n_used = -(-max_plen // R)
    stats.n_mssort += n_used
    stats.sort_elems += int(plens.sum())
    stats.chunk_loads += n_used
    stats.chunk_stores += n_used
    n_rounds = max(buckets).bit_length() - 1
    steps_acc = [np.zeros(max(buckets) >> (k + 1), np.int64)
                 for k in range(n_rounds)]
    tails_acc = [np.zeros((max(buckets) >> (k + 1), 2), np.int64)
                 for k in range(n_rounds)]
    zip_elems = 0
    for C_b in sorted(buckets):
        idxs = buckets[C_b]
        Nb = 1 << max(0, len(idxs) - 1).bit_length()
        row_ids = np.full(Nb, -1, np.int32)
        lane_ids = np.zeros(Nb, np.int32)
        for t, ix in enumerate(idxs):
            lane_ids[t], row_ids[t] = items[ix]
        mk, mv, ml, rounds = _fused_bucket(
            jnp.asarray(row_ids), jnp.asarray(lane_ids), *mats,
            R=R, L=C_b * R, backend=kb.resolve_backend(backend).name)
        mk, mv, ml = np.asarray(mk), np.asarray(mv), np.asarray(ml)
        for k, (st, ze, tl) in enumerate(rounds):
            st, tl = np.asarray(st), np.asarray(tl)
            np.maximum(steps_acc[k][:len(st)], st,
                       out=steps_acc[k][:len(st)])
            np.maximum(tails_acc[k][:len(tl)], tl,
                       out=tails_acc[k][:len(tl)])
            zip_elems += int(np.asarray(ze))
        if coo is not None:
            valid = np.arange(mk.shape[1])[None, :] < ml[:, None]
            coo.append((np.repeat(row_ids, ml), mk[valid], mv[valid]))
        else:
            for t, ix in enumerate(idxs):
                it = items[ix]
                out_k[it] = mk[t, :ml[t]]
                out_v[it] = mv[t, :ml[t]]
    n_zip = sum(int(s.sum()) for s in steps_acc)
    stats.n_mszip += n_zip
    stats.zip_elems += zip_elems
    stats.chunk_loads += 2 * n_zip
    stats.chunk_stores += n_zip + sum(int(t.sum()) for t in tails_acc)


def _group_cap(Sg: int, S: int) -> int:
    """Pad kernel issues to the next pow2 >= Sg (capped at S): bounds the
    number of distinct compiled shapes without inflating a small matrix's
    groups all the way to S streams."""
    return min(S, 1 << max(0, Sg - 1).bit_length())


def _spz_host_driver(A, B, R, S, order, backend, stats):
    """The paper-faithful lock-step Python driver: one kernel issue per
    chunk, numpy marshaling between issues (stats carry the per-phase
    wall-clock breakdown used by the Fig. 9 benchmark)."""
    a_indptr, a_idx, a_val = csr_to_numpy(A)
    b_indptr, b_idx, b_val = csr_to_numpy(B)
    out_rows_k = [None] * A.n_rows
    out_rows_v = [None] * A.n_rows
    for g0 in range(0, A.n_rows, S):
        rows = order[g0:g0 + S]
        cap_g = _group_cap(len(rows), S)
        t1 = time.perf_counter()
        products = expand_group(rows, a_indptr, a_idx, a_val,
                                 b_indptr, b_idx, b_val)
        t2 = time.perf_counter()
        stats.t_expand += t2 - t1
        parts = sort_phase(products, R, len(rows), backend, stats,
                           cap_s=cap_g)
        final = merge_tree_host(parts, R, backend, stats, cap_s=cap_g)
        stats.t_sort += time.perf_counter() - t2
        if final is not None:
            Kf, Vf, lf = final
            for s, i in enumerate(rows):
                out_rows_k[i] = Kf[s, :lf[s]]
                out_rows_v[i] = Vf[s, :lf[s]]
        else:
            for i in rows:
                out_rows_k[i] = np.empty(0, np.int32)
                out_rows_v[i] = np.empty(0, np.float32)
    return out_rows_k, out_rows_v


def _spz_fused_driver(A, B, R, S, order, work, backend, stats):
    """Device-resident driver: per lock-step group, the work-bucketed
    expand/sort/merge-tree pipelines run as jitted computations keyed on
    static (N, L, R) buckets.  All chunk pointers live on the device;
    SpzStats counts come back as device counters (wall-clock attribution
    collapses into t_sort)."""
    coo: list = []
    mats = (A.indptr[None], A.indices[None], A.data[None],
            B.indptr[None], B.indices[None], B.data[None])
    for g0 in range(0, A.n_rows, S):
        rows = order[g0:g0 + S]
        items = [(0, int(i)) for i in rows]
        t1 = time.perf_counter()
        fused_process_group(items, work[rows], mats, R, backend, stats,
                            coo=coo)
        stats.t_sort += time.perf_counter() - t1
    return coo


def _coo_parts_to_csr(coo, shape) -> CSR:
    """Assemble the fused driver's vectorized (rows, cols, vals) parts
    into the output CSR, dropping exact zeros like the scalar engines."""
    if not coo:
        return csr_from_coo([], [], [], shape)
    rows = np.concatenate([p[0] for p in coo])
    cols = np.concatenate([p[1] for p in coo])
    vals = np.concatenate([p[2] for p in coo])
    nz = vals != 0.0
    return csr_from_coo(rows[nz], cols[nz], vals[nz], shape)


def _rows_to_csr(out_rows_k, out_rows_v, shape) -> CSR:
    """Assemble per-row key/value slices into the output CSR (empty-safe)."""
    rr, cc, vv = [], [], []
    for i, (k, v) in enumerate(zip(out_rows_k, out_rows_v)):
        nz = v != 0.0
        rr.append(np.full(int(nz.sum()), i, np.int64))
        cc.append(k[nz])
        vv.append(v[nz])
    if not rr:
        return csr_from_coo([], [], [], shape)
    return csr_from_coo(np.concatenate(rr), np.concatenate(cc),
                        np.concatenate(vv), shape)


def spgemm_spz(A: CSR, B: CSR, *, R: int = 16, S: int | None = None,
               rsort: bool = False, backend="auto",
               driver: str = "fused"):
    """Merge-based SpGEMM using the SparseZipper primitives.

    R: chunk width (paper: 16; TPU-native: 128).
    S: lock-step stream count per kernel issue (>= R groups batched into one
       dispatch is allowed — stream semantics are independent — and models a
       multi-issue matrix unit; default 32*R).
    rsort: pre-sort row indices by per-row work (spz-rsort).
    backend: kernel backend for the stream primitives — a registered name
       ("xla", "pallas", "ref"), "auto" (pallas on TPU, xla elsewhere),
       or a resolved ``KernelBackend``; unknown names raise ``ValueError``
       listing the registered backends.  All registered backends are
       bit-compatible, so this is purely a performance knob (the dispatch
       layer resolves it once at plan time).
    driver: "fused" (default) — device-resident pipeline: expansion, chunk
       sort, and the whole zip-merge tree run as ONE jitted computation
       per (S, L, R) bucket, with the data-dependent chunk advancement
       under ``jax.lax.while_loop``; "host" — the original lock-step
       Python driver (one kernel issue per chunk), kept for the
       stats-faithful Fig. 9-11 wall-clock breakdown.  Both produce
       identical outputs and identical mssort/mszip instruction counts.
    Returns (CSR, SpzStats)."""
    S = S or 32 * R
    stats = SpzStats()
    if driver not in ("fused", "host"):
        raise ValueError(f"unknown spz driver {driver!r}; use 'fused'|'host'")
    bk = kb.resolve_backend(backend)  # unknown names raise, listing all
    if A.n_rows == 0:
        # zero output rows: concatenating per-row results would raise
        return csr_from_coo([], [], [], (A.n_rows, B.n_cols)), stats
    t0 = time.perf_counter()
    work = row_work(A, B) if (rsort or driver == "fused") else None
    order = (np.argsort(work, kind="stable") if rsort
             else np.arange(A.n_rows))
    stats.t_preprocess = time.perf_counter() - t0
    if driver == "host":
        out_rows_k, out_rows_v = _spz_host_driver(A, B, R, S, order, bk,
                                                  stats)
        t3 = time.perf_counter()
        out = _rows_to_csr(out_rows_k, out_rows_v, (A.n_rows, B.n_cols))
    else:
        coo = _spz_fused_driver(A, B, R, S, order, work, bk, stats)
        t3 = time.perf_counter()
        out = _coo_parts_to_csr(coo, (A.n_rows, B.n_cols))
    stats.t_output = time.perf_counter() - t3
    return out, stats


def spgemm(A: CSR, B: CSR, method: str = "spz", **kw):
    """Deprecated front-end: use ``repro.core.spgemm(A, B, engine=...)``
    (the canonical dispatch entry re-exported by ``repro.core``).

    ``method`` names map 1:1 onto registered dispatch engines, so this
    thin alias delegates straight to the registry and will be removed
    once nothing imports it."""
    import warnings

    from repro.core import dispatch
    warnings.warn(
        "repro.core.spgemm.spgemm(method=...) is deprecated; call the "
        "canonical repro.core spgemm (core.dispatch.spgemm) with "
        "engine=... instead", DeprecationWarning, stacklevel=2)
    return dispatch.spgemm(A, B, engine=method, **kw)
