"""SpGEMM engine registry, density-aware dispatch, and batched execution.

The paper's central observation (Table III / Fig. 8) is that no single
SpGEMM strategy wins everywhere: scalar hash accumulation, vectorized
Expand-Sort-Compress, and the SparseZipper merge path trade off by density,
per-row work, and work skew. This module turns the five free functions in
``core/spgemm.py`` into a serving-grade engine layer:

  * a **registry** of named engines with declared capabilities (jittable,
    returns-stats, batchable, dtype support) — new engines plug in via
    :func:`register_engine`;
  * :func:`spgemm` — ``spgemm(A, B, engine="auto")`` picks an engine from
    cheap structural features (density, avg work/row, per-group work
    variance) through an overridable heuristic table, or by one-shot
    measurement (``autotune=True``);
  * an **autotune cache** persisted to disk and keyed by shape/nnz bucket,
    so repeated shapes (the serving steady state) skip re-selection;
  * :func:`spgemm_batched` — runs a whole :class:`BatchedCSR` batch through
    a jittable engine under one compilation: ``esc`` via a vmapped core,
    ``spz`` via a lock-step driver that packs rows from every batch lane
    into shared fixed-capacity stream groups.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import inspect
import json
import math
import os
import time
from typing import Callable, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import spgemm as sg
from repro.core.formats import (BatchedCSR, CSR, batch_csr, csr_from_coo,
                                csr_to_numpy)


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """A registered SpGEMM engine and its declared capabilities.

    ``fn(A, B, **kw)`` returns a CSR, or ``(CSR, stats)`` when
    ``returns_stats``. ``jittable`` engines lower to one XLA computation
    with static capacities; ``batchable`` engines additionally support the
    single-compilation :func:`spgemm_batched` path."""

    name: str
    fn: Callable
    jittable: bool = False
    returns_stats: bool = False
    batchable: bool = False
    measure: bool = True  # candidate for autotune measurement
    dtypes: tuple = ("float32",)
    description: str = ""


_REGISTRY: dict[str, EngineSpec] = {}


def register_engine(name: str, fn: Callable, **caps) -> EngineSpec:
    """Register (or replace) an engine under ``name``; see EngineSpec."""
    spec = EngineSpec(name=name, fn=fn, **caps)
    _REGISTRY[name] = spec
    return spec


def get_engine(name: str) -> EngineSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_engines() -> dict[str, EngineSpec]:
    """Snapshot of the registry (name -> spec)."""
    return dict(_REGISTRY)


register_engine("scl-array", sg.spgemm_scl_array,
                description="scalar row loop, dense accumulator row (oracle)")
register_engine("scl-hash", sg.spgemm_scl_hash,
                description="scalar row loop, hash-style unique/accumulate")
register_engine("esc", sg.spgemm_esc, jittable=True, batchable=True,
                description="vectorized Expand-Sort-Compress (vec-radix)")
register_engine("spz", lambda A, B, **kw: sg.spgemm_spz(A, B, **kw),
                jittable=True, returns_stats=True, batchable=True,
                description="SparseZipper chunked stream sort + zip-merge "
                            "(device-resident fused driver by default)")
register_engine("spz-fused",
                lambda A, B, **kw: sg.spgemm_spz(A, B, driver="fused", **kw),
                jittable=True, returns_stats=True, batchable=True,
                measure=False,  # byte-identical to "spz": don't time it twice
                description="spz with the device-resident pipeline pinned: "
                            "expand/sort/zip-merge tree under one jit per "
                            "(N, L, R) bucket")
register_engine("spz-host",
                lambda A, B, **kw: sg.spgemm_spz(A, B, driver="host", **kw),
                returns_stats=True, batchable=True, measure=False,
                description="spz with the lock-step host driver (one kernel "
                            "issue per chunk; stats-faithful Fig. 9-11 path; "
                            "never wins a measurement, so autotune skips it)")
register_engine("spz-rsort",
                lambda A, B, **kw: sg.spgemm_spz(A, B, rsort=True, **kw),
                jittable=True, returns_stats=True, batchable=True,
                description="spz with rows pre-sorted by per-row work")


# ---------------------------------------------------------------------------
# features + heuristic table
# ---------------------------------------------------------------------------

class _FeatureCache:
    """Bounded memo of structural features keyed on operand identity.

    Serving repeats the same matrix objects call after call, and
    ``BENCH_dispatch.json`` shows the ``work_stats`` recompute dominating
    auto-selection (``select_us``).  The key is the operands' buffer
    ``id()`` + shape + nnz + group; entries pin the index buffers so an
    id cannot be recycled while its entry lives, and an ``is`` check on
    hit guards against lookups racing a rebuild."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._entries: collections.OrderedDict = collections.OrderedDict()

    @staticmethod
    def _key(A: CSR, B: CSR, group: int):
        return (id(A.indices), id(B.indices), A.shape, B.shape,
                int(np.asarray(A.indptr)[-1]), int(np.asarray(B.indptr)[-1]),
                group)

    def get(self, A: CSR, B: CSR, group: int) -> Optional[dict]:
        key = self._key(A, B, group)
        hit = self._entries.get(key)
        if hit is not None and hit[1] is A.indices and hit[2] is B.indices:
            self._entries.move_to_end(key)
            self.hits += 1
            return dict(hit[0])
        self.misses += 1
        return None

    def put(self, A: CSR, B: CSR, group: int, feats: dict) -> None:
        self._entries[self._key(A, B, group)] = (feats, A.indices, B.indices)
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = self.misses = 0


_feature_cache = _FeatureCache()


def clear_feature_cache() -> None:
    """Drop all memoized features (benchmarks measure cold selection)."""
    _feature_cache.clear()


def extract_features(A: CSR, B: CSR, group: int = 16) -> dict:
    """Cheap structural features driving engine choice (Table III columns).

    Memoized on the operands' buffer identity/shape/nnz so repeat calls
    on the same matrices (the serving steady state) skip the recompute."""
    feats = _feature_cache.get(A, B, group)
    if feats is None:
        feats = sg.work_stats(A, B, group=group)
        _feature_cache.put(A, B, group, feats)
        feats = dict(feats)  # callers may mutate their copy, not the cache
    return feats


@dataclasses.dataclass(frozen=True)
class HeuristicRule:
    """First matching rule wins; ``predicate`` maps a feature dict to bool."""

    name: str
    predicate: Callable[[dict], bool]
    engine: str


# Ordered density-regime table (paper §V-B intuition):
#   tiny total work      -> scalar hash: vectorized setup cost dominates;
#   dense / heavy rows   -> esc: expansion+radix amortizes, one XLA graph;
#   high work skew       -> spz-rsort: work-sorted rows fix lock-step
#                           imbalance (Fig. 9);
#   everything else      -> spz merge path (duplicates drop out early).
DEFAULT_HEURISTICS: tuple[HeuristicRule, ...] = (
    HeuristicRule("tiny-work", lambda f: f["total_work"] < 2048
                  and f["density"] < 2e-3, "scl-hash"),
    HeuristicRule("dense", lambda f: f["density"] >= 1.5e-2
                  or f["avg_work_per_row"] >= 128.0, "esc"),
    HeuristicRule("skewed", lambda f: f["work_var_per_group"] >= 1.0,
                  "spz-rsort"),
    HeuristicRule("default", lambda f: True, "spz"),
)


def choose_engine(feats: dict,
                  rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
                  ) -> tuple[str, str]:
    """Return (engine_name, rule_name) for a feature dict."""
    for rule in rules:
        if rule.predicate(feats):
            return rule.engine, rule.name
    raise ValueError("no heuristic rule matched (missing default rule?)")


# ---------------------------------------------------------------------------
# persistent autotune cache
# ---------------------------------------------------------------------------

def _nnz_bucket(m: CSR) -> int:
    """log2 bucket of true nnz — shapes in the same bucket share a plan."""
    return int(np.asarray(m.indptr)[-1]).bit_length()


def cache_key(A: CSR, B: CSR) -> str:
    return (f"{A.n_rows}x{A.n_cols}@{_nnz_bucket(A)}"
            f"*{B.n_rows}x{B.n_cols}@{_nnz_bucket(B)}")


class AutotuneCache:
    """Disk-backed map cache_key -> {engine, source}.

    ``source`` records how the entry was made: "heuristic" entries are
    upgraded in place by a later ``autotune=True`` call; "autotune" entries
    are sticky. Default path: ``$REPRO_AUTOTUNE_CACHE`` or
    ``~/.cache/repro/spgemm_autotune.json``. Writes are atomic
    (tmp + rename); a corrupt/missing file starts empty."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or os.environ.get(
            "REPRO_AUTOTUNE_CACHE",
            os.path.join(os.path.expanduser("~"), ".cache", "repro",
                         "spgemm_autotune.json"))
        self._entries: Optional[dict] = None

    def _load(self) -> dict:
        if self._entries is None:
            try:
                with open(self.path) as f:
                    data = json.load(f)
                self._entries = data if isinstance(data, dict) else {}
            except (OSError, ValueError):
                self._entries = {}
        return self._entries

    def get(self, key: str) -> Optional[dict]:
        return self._load().get(key)

    def put(self, key: str, engine: str, source: str) -> None:
        self._load()[key] = {"engine": engine, "source": source}
        self._flush()

    def _flush(self) -> None:
        tmp = f"{self.path}.tmp.{os.getpid()}"
        try:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            with open(tmp, "w") as f:
                json.dump(self._entries, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)
        except OSError:
            # cache is an optimization; never fail the multiply over it
            try:
                os.unlink(tmp)
            except OSError:
                pass

    def clear(self) -> None:
        self._entries = {}
        self._flush()

    def __len__(self) -> int:
        return len(self._load())


_default_cache: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    global _default_cache
    if _default_cache is None:
        _default_cache = AutotuneCache()
    return _default_cache


def _measure(spec: EngineSpec, A: CSR, B: CSR, repeat: int = 1) -> float:
    best = math.inf
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = spec.fn(A, B)
        if spec.returns_stats:
            out = out[0]
        jax.block_until_ready(out.data)
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# dispatch entry points
# ---------------------------------------------------------------------------

def _filter_kwargs(fn: Callable, kw: dict) -> dict:
    """Keep only kwargs ``fn`` accepts (everything, if it takes **kw).

    Auto-selection may route to any engine, so engine-specific kwargs
    (e.g. spz's ``R``) must not crash a run that picked a different
    engine; explicitly named engines still get strict kwargs."""
    try:
        params = inspect.signature(fn).parameters.values()
    except (TypeError, ValueError):
        return kw
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return kw
    names = {p.name for p in params}
    return {k: v for k, v in kw.items() if k in names}


def spgemm(A: CSR, B: CSR, engine: str = "auto", *,
           autotune: bool = False,
           cache: Optional[AutotuneCache] = None,
           rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
           return_stats: bool = False,
           **kw):
    """Multiply two padded CSR matrices through the engine registry.

    engine:  a registered name, or "auto" to select by cached plan /
             heuristic features / measurement.
    autotune: with engine="auto", time every registered engine on this
             input once and cache the winner for the shape/nnz bucket.
    cache:   AutotuneCache override (default: process-wide disk cache).
             Non-default ``rules`` bypass the cache entirely — a cached
             plan from other rules must not shadow the caller's table,
             nor may a custom-rule choice poison the shared cache.
    return_stats: also return the engine's stats object (None for engines
             without ``returns_stats``).
    """
    if A.n_cols != B.n_rows:
        raise ValueError(f"inner dims differ: {A.shape} @ {B.shape}")
    selected = engine
    if engine == "auto":
        use_cache = rules is DEFAULT_HEURISTICS
        if cache is None:  # NB: `or` would drop an *empty* caller cache
            cache = default_cache()
        key = cache_key(A, B)
        hit = cache.get(key) if use_cache else None
        if hit is not None and (hit["source"] == "autotune" or not autotune):
            selected = hit["engine"]
        elif autotune:
            timings = {name: _measure(spec, A, B)
                       for name, spec in _REGISTRY.items() if spec.measure}
            selected = min(timings, key=timings.get)
            cache.put(key, selected, "autotune")
        else:
            selected, _rule = choose_engine(extract_features(A, B), rules)
            if use_cache:
                cache.put(key, selected, "heuristic")
    spec = get_engine(selected)
    out = spec.fn(A, B, **(_filter_kwargs(spec.fn, kw)
                           if engine == "auto" else kw))
    out, stats = out if spec.returns_stats else (out, None)
    return (out, stats) if return_stats else out


def explain(A: CSR, B: CSR,
            rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS) -> dict:
    """Dry-run selection: features + the rule and engine 'auto' would pick
    (ignoring any cached plan) — for benchmarks and debugging."""
    feats = extract_features(A, B)
    engine, rule = choose_engine(feats, rules)
    return {"engine": engine, "rule": rule, "features": feats,
            "cache_key": cache_key(A, B)}


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

# vmapped unjitted ESC core, jitted once over the whole batch: every lane
# shares the static (cap_products, n_rows, n_cols) plan.
_esc_batched_core = jax.jit(
    jax.vmap(sg._esc_core_impl,
             in_axes=(0, 0, 0, 0, 0, 0, None, None, None)),
    static_argnums=(6, 7, 8))


def _pow2_at_least(n: int) -> int:
    return 1 << max(4, int(n - 1).bit_length())


def _esc_batched(A: BatchedCSR, B: BatchedCSR,
                 cap_products: Optional[int] = None) -> list:
    """One-compilation ESC over a batch: shared power-of-two product
    capacity so ragged batches of similar size reuse the same XLA plan."""
    if cap_products is None:
        works = [int(sg.row_work(a, B[i]).sum()) for i, a in A.lanes()]
        cap_products = _pow2_at_least(max(works + [1]))
    r, c, v, valid, _ = _esc_batched_core(
        A.indptr, A.indices, A.data, B.indptr, B.indices, B.data,
        cap_products, A.n_rows, B.n_cols)
    r, c, v, valid = map(np.asarray, (r, c, v, valid))
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    return [csr_from_coo(r[i][valid[i]], c[i][valid[i]], v[i][valid[i]],
                         (A.n_rows, B.n_cols)) if lane_ok[i] else None
            for i in range(A.batch)]


def _spz_batched(A: BatchedCSR, B: BatchedCSR, *, R: int = 16,
                 S: Optional[int] = None, rsort: bool = False,
                 impl: str = "auto", driver: str = "fused") -> list:
    """Batched SparseZipper driver: rows from *every* valid lane are packed
    into shared lock-step groups of S streams.  The default "fused" driver
    feeds each group through the device-resident expand/sort/merge-tree
    pipeline straight from the stacked BatchedCSR arrays (per-stream lane
    ids index the batch axis); ``driver="host"`` keeps the original
    chunk-at-a-time lock-step loop."""
    S = S or 32 * R
    if driver not in ("fused", "host"):
        raise ValueError(f"unknown spz driver {driver!r}; use 'fused'|'host'")
    stats = sg.SpzStats()
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    valid_lanes = [i for i in range(A.batch) if lane_ok[i]]
    items = [(i, int(r)) for i in valid_lanes for r in range(A.n_rows)]
    # only the host driver walks per-lane numpy copies; the fused driver
    # reads the stacked device arrays directly
    lanes = ({i: (csr_to_numpy(A[i]), csr_to_numpy(B[i]))
              for i in valid_lanes} if driver == "host" else None)
    work = None
    if rsort or driver == "fused":
        work = {i: sg.row_work(A[i], B[i]) for i in valid_lanes}
    if rsort:
        items.sort(key=lambda it: int(work[it[0]][it[1]]))
    out_k = {it: np.empty(0, np.int32) for it in items}
    out_v = {it: np.empty(0, np.float32) for it in items}
    if driver == "fused":
        mats = (A.indptr, A.indices, A.data, B.indptr, B.indices, B.data)
        for g0 in range(0, len(items), S):
            group = items[g0:g0 + S]
            plens = np.array([work[ln][r] for ln, r in group], np.int64)
            sg._fused_process_group(group, plens, mats, R, impl, stats,
                                    out_k, out_v)
    else:
        for g0 in range(0, len(items), S):
            group = items[g0:g0 + S]
            products = []
            for lane, row in group:
                (a_indptr, a_idx, a_val), (b_indptr, b_idx, b_val) = \
                    lanes[lane]
                products.extend(sg._expand_group(
                    [row], a_indptr, a_idx, a_val, b_indptr, b_idx, b_val))
            parts = sg._sort_phase(products, R, len(group), impl, stats,
                                   cap_s=S)
            final = sg._merge_tree(parts, R, impl, stats, cap_s=S)
            if final is not None:
                Kf, Vf, lf = final
                for s, it in enumerate(group):
                    out_k[it] = Kf[s, :lf[s]]
                    out_v[it] = Vf[s, :lf[s]]
    results = []
    for i in range(A.batch):
        if not lane_ok[i]:
            results.append(None)
            continue
        rr, cc, vv = [], [], []
        for row in range(A.n_rows):
            k, v = out_k[(i, row)], out_v[(i, row)]
            nz = v != 0.0
            rr.append(np.full(int(nz.sum()), row, np.int64))
            cc.append(k[nz])
            vv.append(v[nz])
        results.append(csr_from_coo(
            np.concatenate(rr) if rr else [],
            np.concatenate(cc) if cc else [],
            np.concatenate(vv) if vv else [], (A.n_rows, B.n_cols)))
    return results


# auto selection for batches maps any single-matrix choice onto the nearest
# batchable engine (the scalar engines have no single-compilation path)
_BATCH_FALLBACK = {"scl-array": "esc", "scl-hash": "esc"}


def spgemm_batched(A: BatchedCSR, B: BatchedCSR, engine: str = "auto", *,
                   rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
                   **kw) -> BatchedCSR:
    """Multiply a batch of same-shape CSR pairs under one compilation.

    engine: "esc", "spz", "spz-rsort", or "auto" (features of the heaviest
    valid lane pick the engine, then map onto a batchable one). Invalid
    lanes pass through as empty matrices with ``valid=False``. Returns a
    BatchedCSR whose lane capacity is the max output nnz."""
    if A.batch != B.batch or A.n_cols != B.n_rows:
        raise ValueError(f"batch mismatch: {A.batch}x{A.shape} @ "
                         f"{B.batch}x{B.shape}")
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    if not lane_ok.any():
        raise ValueError("no valid lanes in batch")
    selected = engine
    if engine == "auto":
        i_heavy = max((i for i, _ in A.lanes()),
                      key=lambda i: int(np.asarray(A[i].indptr)[-1]))
        selected, _ = choose_engine(
            extract_features(A[i_heavy], B[i_heavy]), rules)
    remapped = _BATCH_FALLBACK.get(selected, selected)
    spec = get_engine(remapped)
    if not spec.batchable:
        raise ValueError(f"engine {remapped!r} has no batched path")
    if remapped == "esc":
        driver = _esc_batched
    elif remapped == "spz":
        driver = _spz_batched
    elif remapped == "spz-fused":
        driver = functools.partial(_spz_batched, driver="fused")
    elif remapped == "spz-host":
        driver = functools.partial(_spz_batched, driver="host")
    elif remapped == "spz-rsort":
        driver = functools.partial(_spz_batched, rsort=True)
    else:
        raise ValueError(f"engine {remapped!r} declared batchable but has "
                         "no batched driver")
    # auto selection / fallback remap may land on any driver: drop kwargs
    # it can't take (explicitly named engines keep strict kwargs)
    if engine == "auto" or remapped != engine:
        kw = _filter_kwargs(driver, kw)
    outs = driver(A, B, **kw)
    empty = csr_from_coo([], [], [], (A.n_rows, B.n_cols))
    cap = max(int(np.asarray(o.indptr)[-1]) for o in outs if o is not None)
    batched = batch_csr([o if o is not None else empty for o in outs],
                        nnz_cap=max(cap, 1))
    return BatchedCSR(batched.indptr, batched.indices, batched.data,
                      jnp.asarray(A.valid) & jnp.asarray(B.valid), batched.shape)
