"""SpGEMM engine registry, plan/execute dispatch, and batched execution.

The paper's central observation (Table III / Fig. 8) is that no single
SpGEMM strategy wins everywhere: scalar hash accumulation, vectorized
Expand-Sort-Compress, and the SparseZipper merge path trade off by density,
per-row work, and work skew. This module turns the five free functions in
``core/spgemm.py`` into a serving-grade engine layer, split into a
**selection** phase and an **execution** phase:

  * a **registry** of named engines with declared capabilities (jittable,
    returns-stats, batchable, dtype support) — new engines plug in via
    :func:`register_engine`;
  * :func:`plan` — ``plan(A, B, engine="auto")`` resolves everything
    data-dependent about a multiply *before* it runs: the engine (from
    cheap structural features through an overridable heuristic table, a
    cached prior selection, or one-shot measurement with
    ``autotune=True``), the resolved engine kwargs, and the static
    capacities that key the jit cache.  Plans are frozen, hashable, and
    reusable across calls with matching operand structure;
  * :func:`execute` — runs a plan against concrete operands.
    ``spgemm(A, B, ...)`` is exactly ``execute(plan(A, B, ...), A, B)``;
  * an **autotune cache** persisted to disk and keyed by shape/nnz bucket,
    so repeated shapes (the serving steady state) skip re-selection, plus
    an in-process plan memo keyed on operand identity so repeat calls on
    the same matrices skip planning entirely;
  * :func:`plan_batched` / :func:`execute_batched` — the same split for a
    whole :class:`BatchedCSR` batch under one compilation: ``esc`` via a
    vmapped core, ``spz`` via a lock-step driver that packs rows from
    every batch lane into shared fixed-capacity stream groups.
    ``distributed/spgemm_shard.py`` layers work-balanced multi-device
    lane sharding on top of these plans.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import inspect
import json
import math
import os
import tempfile
import threading
import time
from typing import Any, Callable, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

# NB: ``repro.core.__init__`` binds the engines module under the alias
# ``spgemm_engines`` *before* importing this module, then re-exports
# ``dispatch.spgemm`` under the package-level name ``spgemm`` — so the
# alias (not ``from repro.core import spgemm``) is the stable way to
# reach the module once the package is initialized.
from repro.core import spgemm as sg
from repro.core.formats import (BatchedCSR, CSR, batch_csr, csr_from_coo,
                                csr_to_numpy, validate_operands)
from repro.kernels import backend as kb
from repro.runtime import faultinject as fi

try:  # best-effort file locking for the autotune-cache flush
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX platforms
    fcntl = None


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """A registered SpGEMM engine and its declared capabilities.

    ``fn(A, B, **kw)`` returns a CSR, or ``(CSR, stats)`` when
    ``returns_stats``. ``jittable`` engines lower to one XLA computation
    with static capacities; ``batchable`` engines additionally support the
    single-compilation :func:`spgemm_batched` path; ``backend_aware``
    engines take a ``backend=`` kernel-backend kwarg (resolved once at
    plan time from the registry in ``kernels/backend.py``)."""

    name: str
    fn: Callable
    jittable: bool = False
    returns_stats: bool = False
    batchable: bool = False
    measure: bool = True  # candidate for autotune measurement
    backend_aware: bool = False
    dtypes: tuple = ("float32",)
    description: str = ""


_REGISTRY: dict[str, EngineSpec] = {}


def register_engine(name: str, fn: Callable, **caps) -> EngineSpec:
    """Register (or replace) an engine under ``name``; see EngineSpec."""
    spec = EngineSpec(name=name, fn=fn, **caps)
    _REGISTRY[name] = spec
    return spec


def get_engine(name: str) -> EngineSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_engines() -> dict[str, EngineSpec]:
    """Snapshot of the registry (name -> spec)."""
    return dict(_REGISTRY)


register_engine("scl-array", sg.spgemm_scl_array,
                description="scalar row loop, dense accumulator row (oracle)")
register_engine("scl-hash", sg.spgemm_scl_hash,
                description="scalar row loop, hash-style unique/accumulate")
register_engine("esc", sg.spgemm_esc, jittable=True, batchable=True,
                description="vectorized Expand-Sort-Compress (vec-radix)")
register_engine("spz", lambda A, B, **kw: sg.spgemm_spz(A, B, **kw),
                jittable=True, returns_stats=True, batchable=True,
                backend_aware=True,
                description="SparseZipper chunked stream sort + zip-merge "
                            "(device-resident fused driver by default)")
register_engine("spz-fused",
                lambda A, B, **kw: sg.spgemm_spz(A, B, driver="fused", **kw),
                jittable=True, returns_stats=True, batchable=True,
                measure=False,  # byte-identical to "spz": don't time it twice
                backend_aware=True,
                description="spz with the device-resident pipeline pinned: "
                            "expand/sort/zip-merge tree under one jit per "
                            "(N, L, R) bucket")
register_engine("spz-host",
                lambda A, B, **kw: sg.spgemm_spz(A, B, driver="host", **kw),
                returns_stats=True, batchable=True, measure=False,
                backend_aware=True,
                description="spz with the lock-step host driver (one kernel "
                            "issue per chunk; stats-faithful Fig. 9-11 path; "
                            "never wins a measurement, so autotune skips it)")
register_engine("spz-rsort",
                lambda A, B, **kw: sg.spgemm_spz(A, B, rsort=True, **kw),
                jittable=True, returns_stats=True, batchable=True,
                backend_aware=True,
                description="spz with rows pre-sorted by per-row work")


# ---------------------------------------------------------------------------
# features + heuristic table
# ---------------------------------------------------------------------------

class _OperandMemo:
    """Bounded memo keyed on operand identity + a request discriminator.

    Serving repeats the same matrix objects call after call, and
    ``BENCH_dispatch.json`` shows the selection work (``work_stats``
    recompute, cache lookups) dominating auto-dispatch (``select_us``).
    The key is the operands' buffer ``id()`` + shape + nnz + ``extra``
    (the feature group, or the full plan request); entries pin the index
    buffers so an id cannot be recycled while its entry lives, and an
    ``is`` check on hit guards against lookups racing a rebuild.  One
    instance memoizes feature dicts, another whole ExecutionPlans.
    Access is lock-guarded: async serving plans concurrent flushes from
    executor threads against these module-level memos."""

    def __init__(self, maxsize: int = 128):
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self._mu = threading.Lock()
        self._entries: collections.OrderedDict = collections.OrderedDict()

    @staticmethod
    def _key(A: CSR, B: CSR, extra):
        return (id(A.indices), id(B.indices), A.shape, B.shape,
                int(np.asarray(A.indptr)[-1]), int(np.asarray(B.indptr)[-1]),
                extra)

    def get(self, A: CSR, B: CSR, extra) -> Optional[Any]:
        key = self._key(A, B, extra)
        with self._mu:
            hit = self._entries.get(key)
            if hit is not None and hit[1] is A.indices \
                    and hit[2] is B.indices:
                self._entries.move_to_end(key)
                self.hits += 1
                return hit[0]
            self.misses += 1
            return None

    def put(self, A: CSR, B: CSR, extra, value) -> None:
        with self._mu:
            self._entries[self._key(A, B, extra)] = (value, A.indices,
                                                     B.indices)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)

    def clear(self) -> None:
        with self._mu:
            self._entries.clear()
        self.hits = self.misses = 0


_feature_cache = _OperandMemo()
_plan_memo = _OperandMemo()


def clear_feature_cache() -> None:
    """Drop memoized features and plans (benchmarks measure cold selection)."""
    _feature_cache.clear()
    _plan_memo.clear()


def extract_features(A: CSR, B: CSR, group: int = 16) -> dict:
    """Cheap structural features driving engine choice (Table III columns).

    Memoized on the operands' buffer identity/shape/nnz so repeat calls
    on the same matrices (the serving steady state) skip the recompute."""
    feats = _feature_cache.get(A, B, group)
    if feats is None:
        feats = sg.work_stats(A, B, group=group)
        _feature_cache.put(A, B, group, feats)
    return dict(feats)  # callers may mutate their copy, not the cache


@dataclasses.dataclass(frozen=True)
class HeuristicRule:
    """First matching rule wins; ``predicate`` maps a feature dict to bool."""

    name: str
    predicate: Callable[[dict], bool]
    engine: str


# Ordered density-regime table (paper §V-B intuition):
#   tiny total work      -> scalar hash: vectorized setup cost dominates;
#   dense / heavy rows   -> esc: expansion+radix amortizes, one XLA graph;
#   high work skew       -> spz-rsort: work-sorted rows fix lock-step
#                           imbalance (Fig. 9);
#   everything else      -> spz merge path (duplicates drop out early).
DEFAULT_HEURISTICS: tuple[HeuristicRule, ...] = (
    HeuristicRule("tiny-work", lambda f: f["total_work"] < 2048
                  and f["density"] < 2e-3, "scl-hash"),
    HeuristicRule("dense", lambda f: f["density"] >= 1.5e-2
                  or f["avg_work_per_row"] >= 128.0, "esc"),
    HeuristicRule("skewed", lambda f: f["work_var_per_group"] >= 1.0,
                  "spz-rsort"),
    HeuristicRule("default", lambda f: True, "spz"),
)


def choose_engine(feats: dict,
                  rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
                  ) -> tuple[str, str]:
    """Return (engine_name, rule_name) for a feature dict."""
    for rule in rules:
        if rule.predicate(feats):
            return rule.engine, rule.name
    raise ValueError("no heuristic rule matched (missing default rule?)")


# ---------------------------------------------------------------------------
# persistent autotune cache
# ---------------------------------------------------------------------------

def _nnz_bucket(m: CSR) -> int:
    """log2 bucket of true nnz — shapes in the same bucket share a plan."""
    return int(np.asarray(m.indptr)[-1]).bit_length()


def cache_key(A: CSR, B: CSR, backend: Optional[str] = None) -> str:
    """Shape/nnz bucket key, extended with the *requested* kernel backend
    so an explicitly pinned backend autotunes its own bucket (a "pallas"
    measurement must never serve an "xla" request, and vice versa).
    ``"auto"`` requests keep the bare key — the default bucket, whose
    entries may record the backend an autotune sweep picked."""
    key = (f"{A.n_rows}x{A.n_cols}@{_nnz_bucket(A)}"
           f"*{B.n_rows}x{B.n_cols}@{_nnz_bucket(B)}")
    return key if backend in (None, "auto") else f"{key}|bk={backend}"


# quarantine records ride in the same JSON file under a reserved key
# prefix (shape keys are "<rows>x<cols>@..." strings, so no collision)
_QUAR_PREFIX = "!quarantine:"

# the cache file's schema record (same reserved "!" namespace).  v1 files
# (no record) held winner-only selection entries and TTL-less quarantine
# records; v2 adds per-candidate timing vectors + feature dicts on
# autotune entries and per-combo quarantine timestamps/strike counts.
# Old entries are MIGRATED forward on load, never dropped: a winner-only
# v1 entry is a perfectly good v2 entry without a timing vector.
_SCHEMA_KEY = "!schema"
SCHEMA_VERSION = 2


def combo_str(engine: str, backend: Optional[str]) -> str:
    """The canonical "engine|backend" id shared by quarantine records,
    timing vectors, and the dispatch model's candidate space ("" for a
    backend-less engine)."""
    return f"{engine}|{backend or ''}"


def split_combo(combo: str) -> tuple[str, Optional[str]]:
    engine, _, backend = combo.partition("|")
    return engine, (backend or None)

# returned by AutotuneCache._lock_file when a live holder kept the lock
# past the bounded acquire window (distinct from None = "no locking")
_LOCK_TIMEOUT = object()


class AutotuneCache:
    """Disk-backed map cache_key -> {engine, source[, backend]}.

    ``source`` records how the entry was made: "heuristic" entries are
    upgraded in place by a later ``autotune=True`` call; "autotune" entries
    are sticky.  ``backend`` (optional) records the winning kernel backend
    for backend-aware engines.  Default path: ``$REPRO_AUTOTUNE_CACHE`` or
    ``~/.cache/repro/spgemm_autotune.json``.

    Robustness (shared by concurrent serving processes): a corrupt or
    truncated file is moved aside to ``<path>.corrupt`` and the cache
    starts empty instead of crashing; writes go to a unique tempfile and
    are published with an atomic rename, so readers never observe a
    partial file; and every flush re-reads and merges the current
    on-disk entries (an "autotune" entry from another process is never
    downgraded by this process's "heuristic" one) under a best-effort
    ``fcntl`` file lock (``<path>.lock``) that serializes the
    read-merge-write critical section across processes — on platforms
    without ``fcntl`` the lock is a no-op and the merge falls back to
    the previous shrunk-loss-window behaviour, where a dropped entry
    only costs a re-measurement, never correctness.  The lock acquire
    is *bounded* (``lock_timeout_s``, default 0.5s or
    ``$REPRO_AUTOTUNE_LOCK_TIMEOUT_S``): a hung — not dead — lock
    holder costs a skipped flush, never a stalled serving process.

    Cross-process propagation protocol (the multi-process serving
    substrate): **push on quarantine** — ``quarantine()`` flushes
    immediately, so a combo poisoned by one worker process lands on
    disk right away, not at process exit; **pull on plan miss** —
    ``plan()``/``plan_batched()`` call :meth:`refresh` before giving up
    on a cache miss, so a fresh bucket picks up selections and poison
    other processes pushed since this process loaded the file.  Net
    effect: a kernel crash observed in one process is routed around by
    every process within one flush interval."""

    def __init__(self, path: Optional[str] = None, *,
                 lock_timeout_s: Optional[float] = None,
                 quarantine_ttl_s: Optional[float] = None,
                 clock: Callable[[], float] = time.time):
        self.path = path or os.environ.get(
            "REPRO_AUTOTUNE_CACHE",
            os.path.join(os.path.expanduser("~"), ".cache", "repro",
                         "spgemm_autotune.json"))
        self._entries: Optional[dict] = None
        # bumped whenever a memoized plan may have been invalidated
        # (autotune upgrades, clears, pulled quarantines) — keyed into
        # the plan memo
        self.version = 0
        # serializes in-process access (async flush threads share one
        # cache object); the fcntl file lock covers cross-process
        self._mu = threading.RLock()
        if lock_timeout_s is None:
            lock_timeout_s = float(os.environ.get(
                "REPRO_AUTOTUNE_LOCK_TIMEOUT_S", "0.5"))
        self.lock_timeout_s = lock_timeout_s
        if quarantine_ttl_s is None:
            quarantine_ttl_s = float(os.environ.get(
                "REPRO_QUARANTINE_TTL_S", "3600"))
        self.quarantine_ttl_s = quarantine_ttl_s
        self.clock = clock
        # (st_mtime_ns, st_size, st_ino) of the last disk state we
        # parsed — lets refresh() skip the JSON re-parse when nothing
        # was flushed since (the plan-miss pull runs per miss)
        self._disk_stat: Optional[tuple] = None
        # schema version of the file as loaded (pre-migration), for
        # inspection tools; None until the file is first read
        self.loaded_schema_version: Optional[int] = None

    def _migrate(self, data: dict) -> dict:
        """Normalize entries from any prior schema version in place.

        Migration is strictly additive — a version bump must never
        discard winner entries another (older) process wrote:
          * selection entries (winner-only v1 or timing-vectored v2)
            pass through unchanged — absent ``timings``/``features``
            just means "no replayable measurement for this bucket";
          * v1 quarantine records carry no per-combo timestamps; they
            are stamped *now* so a combo poisoned before TTLs existed
            gets one full TTL from this load instead of being poisoned
            forever (the exact failure the TTL exists to fix)."""
        now = float(self.clock())
        for k, v in data.items():
            if not k.startswith(_QUAR_PREFIX):
                continue
            ts = v.setdefault("ts", {})
            for combo in v.get("combos", ()):
                ts.setdefault(combo, now)
        return data

    def _read_disk(self) -> Optional[dict]:
        """Parse + migrate the on-disk file; {} when missing, None when
        corrupt.  Records the file's stat identity for refresh()."""
        try:
            with open(self.path) as f:
                st = os.fstat(f.fileno())
                data = json.load(f)
        except FileNotFoundError:
            self._disk_stat = None
            return {}
        except (OSError, ValueError):
            return None
        if not isinstance(data, dict):
            return None
        self._disk_stat = (st.st_mtime_ns, st.st_size, st.st_ino)
        schema = data.pop(_SCHEMA_KEY, None)
        self.loaded_schema_version = int(schema.get("version", 1)) \
            if isinstance(schema, dict) else 1
        return self._migrate(
            {k: v for k, v in data.items() if isinstance(v, dict)})

    def _load(self) -> dict:
        if self._entries is None:
            disk = self._read_disk()
            if disk is None:
                # corrupted/truncated: preserve the evidence, start empty
                try:
                    os.replace(self.path, self.path + ".corrupt")
                except OSError:
                    pass
                disk = {}
            self._entries = disk
        return self._entries

    def get(self, key: str) -> Optional[dict]:
        with self._mu:
            return self._load().get(key)

    def put(self, key: str, engine: str, source: str,
            backend: Optional[str] = None, *,
            timings: Optional[dict] = None,
            features: Optional[dict] = None) -> None:
        """Record a selection; autotune sweeps additionally log the FULL
        per-candidate timing vector (``timings``: combo string ->
        seconds) and the feature dict that drove it — the replayable
        dataset the learned dispatch model trains on."""
        with self._mu:
            entry: dict[str, Any] = {"engine": engine, "source": source}
            if backend is not None:
                entry["backend"] = backend
            if timings:
                entry["timings"] = {k: float(v) for k, v in timings.items()}
            if features:
                entry["features"] = {k: (float(v) if isinstance(v, float)
                                         else int(v))
                                     for k, v in features.items()}
            self._load()[key] = entry
            if source == "autotune":
                self.version += 1
            self._flush()

    def entries(self) -> dict:
        """Snapshot of every record (selections + ``!quarantine:`` keys)
        — the offline-training dataset export and the inspection surface
        for ``tools/dump_autotune.py``."""
        with self._mu:
            return {k: dict(v) for k, v in self._load().items()}

    # -- quarantine: poisoned (engine, backend) combos per shape bucket --

    @staticmethod
    def _combo(engine: str, backend: Optional[str]) -> str:
        return combo_str(engine, backend)

    def _quarantine_ttl(self, q: dict, combo: str) -> float:
        """Effective TTL for a combo: the base TTL doubled per strike
        (a combo that keeps crashing on re-probe earns exponentially
        longer quarantines, capped at 16x) — the re-probe budget."""
        strikes = int(q.get("strikes", {}).get(combo, 1))
        return self.quarantine_ttl_s * min(2.0 ** (strikes - 1), 16.0)

    def _quarantine_active(self, q: dict, combo: str) -> bool:
        """Whether a combo is currently poisoned (listed and unexpired).

        An expired combo is *re-admitted*: dropped from the active list
        (its strike count survives, so a re-crash re-quarantines it for
        longer) lazily here rather than by a sweeper.  The removal is
        in-memory only — the next flush persists it; until then other
        processes run their own expiry clocks."""
        if combo not in q.get("combos", ()):
            return False
        ts = q.get("ts", {}).get(combo)
        if ts is None:  # unmigrated record mid-merge: stamp, stay active
            q.setdefault("ts", {})[combo] = float(self.clock())
            return True
        if float(self.clock()) - float(ts) < self._quarantine_ttl(q, combo):
            return True
        q["combos"] = [c for c in q["combos"] if c != combo]
        q.get("ts", {}).pop(combo, None)
        return False

    def quarantine(self, key: str, engine: str,
                   backend: Optional[str] = None,
                   reason: str = "") -> None:
        """Mark (engine, backend) poisoned for this shape bucket.

        A kernel that crashes (or returns garbage) for a bucket must not
        be re-selected on the next plan: quarantined combos are skipped
        by cache hits, heuristic selection, and autotune sweeps.  With
        ``backend=None`` the engine is poisoned for every backend.

        Poison is NOT forever: each combo carries a timestamp and the
        quarantine expires after ``quarantine_ttl_s`` (doubled per
        repeat offense), so a transiently-crashing combo — an OOM spike,
        a half-installed kernel build — is re-probed instead of being
        routed around for the life of the cache file."""
        with self._mu:
            entries = self._load()
            qk = _QUAR_PREFIX + key
            q = entries.setdefault(qk, {"combos": []})
            combo = self._combo(engine, backend)
            if combo not in q["combos"]:
                q["combos"].append(combo)
            q.setdefault("ts", {})[combo] = float(self.clock())
            strikes = q.setdefault("strikes", {})
            strikes[combo] = int(strikes.get(combo, 0)) + 1
            if reason:
                q.setdefault("reasons", {})[combo] = reason
            # a selection entry routing to the poisoned combo is dropped
            # so the next plan re-selects among healthy candidates
            sel = entries.get(key)
            if sel is not None and sel.get("engine") == engine and \
                    backend in (None, sel.get("backend")):
                entries.pop(key)
            self.version += 1  # invalidate memoized plans
            self._flush()

    def is_quarantined(self, key: str, engine: str,
                       backend: Optional[str] = None) -> bool:
        with self._mu:
            q = self._load().get(_QUAR_PREFIX + key)
            if not q:
                return False
            return (self._quarantine_active(q, self._combo(engine, backend))
                    or self._quarantine_active(q, self._combo(engine, None)))

    def quarantined(self, key: str) -> list[tuple[str, Optional[str]]]:
        """The (engine, backend) combos actively quarantined for a
        bucket (expired combos are re-admitted, not listed)."""
        with self._mu:
            q = self._load().get(_QUAR_PREFIX + key, {})
            return [(c.split("|", 1)[0], c.split("|", 1)[1] or None)
                    for c in list(q.get("combos", ()))
                    if self._quarantine_active(q, c)]

    def _lock_file(self):
        """Open + exclusively lock ``<path>.lock``.

        Returns the locked file object, ``None`` when locking is
        unavailable (no ``fcntl``, open failure — the unlocked merge
        proceeds), or the :data:`_LOCK_TIMEOUT` sentinel when a live
        holder kept the lock past ``lock_timeout_s`` — the caller skips
        the flush entirely rather than stalling the serving process
        behind a hung peer.  flock serializes the flush's
        read-merge-write across processes (and across cache objects in
        one process — each open is its own file description).  Purely
        best-effort: any failure degrades to a skipped or unlocked
        merge, never to a failed multiply."""
        if fcntl is None:
            return None
        try:
            f = open(self.path + ".lock", "a")
        except OSError:
            return None
        deadline = time.monotonic() + max(0.0, self.lock_timeout_s)
        while True:
            try:
                fcntl.flock(f.fileno(), fcntl.LOCK_EX | fcntl.LOCK_NB)
                return f
            except OSError:
                if time.monotonic() >= deadline:
                    try:
                        f.close()
                    except OSError:
                        pass
                    return _LOCK_TIMEOUT
                time.sleep(0.01)

    def _merge_from(self, disk: dict) -> bool:
        """Merge on-disk entries into memory; True when anything changed.

        Entries concurrent processes flushed since we loaded are kept;
        their measured plans beat our heuristics (quarantine records
        merge by union — a combo poisoned by any process stays
        poisoned).  After the merge, selections routing to poisoned
        combos are swept: the merge may have resurrected a selection
        this process just quarantined (its stale disk entry merged back
        in), or pulled in a selection another process has since
        poisoned."""
        changed = False
        for k, v in disk.items():
            ours = self._entries.get(k)
            if k.startswith(_QUAR_PREFIX):
                if ours is None:
                    self._entries[k] = v
                    changed = True
                else:
                    for c in v.get("combos", ()):
                        if c not in ours["combos"]:
                            ours["combos"].append(c)
                            changed = True
                    # timestamps merge by max (the most recent poisoning
                    # wins the TTL clock), strike counts by max
                    for fld in ("ts", "strikes"):
                        theirs = v.get(fld, {})
                        mine = ours.setdefault(fld, {})
                        for c, val in theirs.items():
                            if float(val) > float(mine.get(c, -math.inf)):
                                mine[c] = val
                                changed = True
                continue
            if ours is None or (v.get("source") == "autotune"
                                and ours.get("source") != "autotune"):
                if ours != v:
                    self._entries[k] = v
                    changed = True
            elif ours.get("source") == v.get("source"):
                # same-rank entries: union in the dataset fields a peer
                # recorded that we lack (its sweep logged timings, ours
                # was a bare winner) — measurements are never discarded
                for fld in ("timings", "features"):
                    if fld in v and fld not in ours:
                        ours[fld] = v[fld]
                        changed = True
                # ... including per-combo timing points a peer's sweep
                # measured for candidates ours skipped (quarantine or
                # backend availability differ across processes)
                theirs_t = v.get("timings")
                ours_t = ours.get("timings")
                if theirs_t and ours_t:
                    for c, t in theirs_t.items():
                        if c not in ours_t:
                            ours_t[c] = t
                            changed = True
        for qk, q in list(self._entries.items()):
            if not qk.startswith(_QUAR_PREFIX):
                continue
            sk = qk[len(_QUAR_PREFIX):]
            sel = self._entries.get(sk)
            if sel is None:
                continue
            eng = sel.get("engine", "")
            if (self._quarantine_active(q, self._combo(eng,
                                                       sel.get("backend")))
                    or self._quarantine_active(q, self._combo(eng, None))):
                self._entries.pop(sk, None)
                changed = True
        return changed

    def refresh(self) -> bool:
        """Pull entries other processes flushed since our last read.

        The "pull" half of the cross-process propagation protocol:
        called on a plan-cache miss (and available to supervisors on
        worker-loss events), it merges the current on-disk state into
        memory without writing anything back.  Bumps :attr:`version`
        when the merge changed anything, so memoized plans built on the
        stale view are invalidated.  Returns whether anything changed."""
        with self._mu:
            if self._entries is None:
                self._load()
                return True
            # stat short-circuit: the pull runs on EVERY plan-cache miss
            # (model-based selection makes misses the common case for
            # fresh buckets), so an unchanged file must cost a stat, not
            # a JSON parse
            try:
                st = os.stat(self.path)
                if self._disk_stat == (st.st_mtime_ns, st.st_size,
                                       st.st_ino):
                    return False
            except OSError:
                pass
            disk = self._read_disk()
            if not disk:
                return False
            changed = self._merge_from(disk)
            if changed:
                self.version += 1
            return changed

    def _flush(self, *, merge: bool = True) -> None:
        with self._mu:
            self._flush_locked(merge=merge)

    def _flush_locked(self, *, merge: bool = True) -> None:
        # merge=False writes the in-memory view verbatim — maintenance
        # rewrites (compact --drop-timings) that must NOT re-union the
        # on-disk dataset fields they just stripped
        tmp = None
        lock = None
        try:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            lock = self._lock_file()
            if lock is _LOCK_TIMEOUT:
                # a hung (not dead) holder: skip this flush — the
                # entries stay in memory and the next flush retries;
                # a skipped write costs a re-measurement, a stall
                # costs the serving process
                lock = None
                return
            fi.fire("autotune.flush", path=self.path)
            if merge:
                self._merge_from(self._read_disk() or {})
            fd, tmp = tempfile.mkstemp(
                dir=os.path.dirname(self.path) or ".",
                prefix=os.path.basename(self.path) + ".tmp.")
            payload = {_SCHEMA_KEY: {"version": SCHEMA_VERSION},
                       **self._entries}
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)
            try:
                st = os.stat(self.path)
                self._disk_stat = (st.st_mtime_ns, st.st_size, st.st_ino)
            except OSError:
                self._disk_stat = None
        except Exception:
            # cache is an optimization; never fail the multiply over it
            # (OSError, a scribbled-on file, or an injected write fault)
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
        finally:
            if lock is not None:
                try:
                    fcntl.flock(lock.fileno(), fcntl.LOCK_UN)
                    lock.close()
                except OSError:
                    pass

    def clear(self) -> None:
        """Drop all entries, in memory and on disk (no merge-back)."""
        with self._mu:
            self._entries = {}
            self._disk_stat = None
            self.version += 1
            try:
                os.unlink(self.path)
            except OSError:
                pass

    def __len__(self) -> int:
        with self._mu:
            return len(self._load())


_default_cache: Optional[AutotuneCache] = None


def default_cache() -> AutotuneCache:
    global _default_cache
    if _default_cache is None:
        _default_cache = AutotuneCache()
    return _default_cache


# ---------------------------------------------------------------------------
# learned cost-model selection (models/dispatch_model.py artifacts)
# ---------------------------------------------------------------------------

# the model artifact lives NEXT TO the cache file it was trained from:
# the cache is the dataset, the model is its fitted view, and serving
# processes that share the cache path automatically share the model
MODEL_SUFFIX = ".model.json"


def model_path_for(cache: AutotuneCache) -> str:
    """Default on-disk path of the dispatch model trained from ``cache``."""
    return cache.path + MODEL_SUFFIX


_model_mu = threading.Lock()
# path -> (mtime_ns, model-or-None): a retrained artifact (new mtime) is
# picked up on the next plan without a restart; a corrupt one caches as
# None so selection does not re-parse it per plan
_model_memo: dict[str, tuple[int, Any]] = {}


def _artifact_mtime_ns(path: str) -> Optional[int]:
    try:
        return os.stat(path).st_mtime_ns
    except OSError:
        return None


def resolve_model(model, cache: AutotuneCache):
    """Resolve plan()'s ``model`` request to a DispatchModel or None.

    ``"auto"`` loads (and memoizes, keyed on file mtime) the artifact
    next to the cache file — absent or unreadable artifacts resolve to
    None and selection falls through to measurement/heuristics; a
    DispatchModel instance is used as-is; False/None disables."""
    if model in (False, None):
        return None
    if model != "auto":  # an explicit DispatchModel (tests, notebooks)
        return model
    path = model_path_for(cache)
    mtime = _artifact_mtime_ns(path)
    if mtime is None:
        return None
    with _model_mu:
        hit = _model_memo.get(path)
        if hit is not None and hit[0] == mtime:
            return hit[1]
    from repro.models import dispatch_model as dm
    try:
        loaded = dm.DispatchModel.load(path)
    except Exception:
        # a corrupt/foreign artifact must never fail a plan
        loaded = None
    with _model_mu:
        _model_memo[path] = (mtime, loaded)
    return loaded


def _model_token(model, cache: AutotuneCache) -> Optional[tuple]:
    """Hashable identity of the model a plan would consult — keyed into
    the plan memo so a retrained artifact invalidates memoized plans."""
    if model in (False, None):
        return None
    if model != "auto":
        return ("obj", id(model))
    return ("file", _artifact_mtime_ns(model_path_for(cache)))


def _model_candidates(key: str, backend: str,
                      cache: AutotuneCache) -> set:
    """Combo strings ("engine|backend") legal for this request: every
    measurable registry candidate minus quarantined combos.  A pinned
    backend restricts backend-aware engines to it, exactly like the
    autotune sweep's candidate list.

    One ``quarantined()`` snapshot instead of per-combo
    ``is_quarantined`` checks: this runs on the plan hot path and each
    check is a lock round-trip."""
    poisoned = {combo_str(e, b) for e, b in cache.quarantined(key)}
    allowed = set()
    for name, bk_name in _measure_candidates(backend):
        c = combo_str(name, bk_name)
        # an engine-wide quarantine (backend=None) poisons every backend
        if c in poisoned or combo_str(name, None) in poisoned:
            continue
        allowed.add(c)
    return allowed


def _model_select(model, feats: dict, key: str, backend: str,
                  cache: AutotuneCache):
    """One model-based selection attempt; None when the model abstains
    (no healthy candidate it knows, or a prediction failure)."""
    if model is None:
        return None
    try:
        return model.select(feats,
                            allowed=_model_candidates(key, backend, cache))
    except Exception:
        return None  # a broken model must never fail a plan


def _measure(spec: EngineSpec, A: CSR, B: CSR, repeat: int = 1,
             backend: Optional[str] = None) -> float:
    kw = {"backend": backend} if backend is not None else {}
    best = math.inf
    for _ in range(repeat):
        fi.fire("dispatch.measure", engine=spec.name, backend=backend)
        t0 = time.perf_counter()
        out = spec.fn(A, B, **kw)
        if spec.returns_stats:
            out = out[0]
        jax.block_until_ready(out.data)
        best = min(best, time.perf_counter() - t0)
    return best


_measure_cands_memo: dict[tuple, list] = {}


def _measure_candidates(backend: str) -> list[tuple[str, Optional[str]]]:
    """(engine, backend) pairs autotune times.  With ``backend="auto"``
    the backend becomes part of the search space: every backend-aware
    engine is measured once per kernel backend measurable on this host
    (``kb.measurable_backends()`` — off-TPU that excludes the
    interpret-mode pallas tier), so a TPU shape bucket can settle on
    e.g. ``spz-fused/pallas`` over ``spz-fused/xla``.  A pinned backend
    is measured as-is.

    Memoized on the (engine, backend) registry contents — this also
    runs per model-assisted plan, where rebuilding the backend list
    would be measurable overhead; registering an engine or backend
    invalidates naturally through the fingerprint key."""
    fp = (backend,
          tuple((n, s.measure, s.backend_aware)
                for n, s in _REGISTRY.items()),
          tuple(sorted((b.name, b.measure, b.needs_tpu_for_perf)
                       for b in kb.available_backends().values())))
    hit = _measure_cands_memo.get(fp)
    if hit is not None:
        return hit
    cands: list[tuple[str, Optional[str]]] = []
    for name, spec in _REGISTRY.items():
        if not spec.measure:
            continue
        if not spec.backend_aware:
            cands.append((name, None))
        elif backend == "auto":
            cands.extend((name, bk.name)
                         for bk in kb.measurable_backends())
        else:
            cands.append((name, kb.resolve_backend(backend).name))
    if len(_measure_cands_memo) > 32:  # registry churn: bound staleness
        _measure_cands_memo.clear()
    _measure_cands_memo[fp] = cands
    return cands


# ---------------------------------------------------------------------------
# plan / execute dispatch
# ---------------------------------------------------------------------------

def _filter_kwargs(fn: Callable, kw: dict) -> dict:
    """Keep only kwargs ``fn`` accepts (everything, if it takes **kw).

    Auto-selection may route to any engine, so engine-specific kwargs
    (e.g. spz's ``R``) must not crash a plan that picked a different
    engine; explicitly named engines still get strict kwargs.  Runs once
    at *plan* time — execution never re-inspects signatures."""
    try:
        params = inspect.signature(fn).parameters.values()
    except (TypeError, ValueError):
        return kw
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in params):
        return kw
    names = {p.name for p in params}
    return {k: v for k, v in kw.items() if k in names}


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Everything selection decides about a multiply, frozen and hashable.

    A plan captures the engine choice, the kwargs resolved against that
    engine's signature, and the static-capacity facts (shapes, nnz work
    bucket, batch lane count) that determine which compiled XLA
    computation execution lands on — ``jit_key`` is that identity, so
    two plans with equal ``jit_key`` reuse one compilation.  Plans are
    inspectable (the serving layer logs ``engine``/``source`` per
    flush), reusable across calls whose operands match the planned
    structure, and cacheable by hash."""

    engine: str                 # resolved engine (post fallback remap)
    batched: bool               # single CSR pair vs BatchedCSR lanes
    a_shape: tuple
    b_shape: tuple
    kwargs: tuple               # sorted (name, value) pairs, plan-resolved
    work_bucket: tuple          # (nnz bucket A, nnz bucket B) — jit-relevant
    cache_key: str              # autotune-cache key the selection used
    source: str    # "explicit" | "heuristic" | "cache" | "autotune" | "model"
    rule: Optional[str] = None  # heuristic rule that fired (source="heuristic")
    batch: Optional[int] = None  # lane capacity (batched plans only)
    backend: Optional[str] = None  # resolved kernel backend (aware engines)

    @property
    def kwargs_dict(self) -> dict:
        return dict(self.kwargs)

    @property
    def jit_key(self) -> tuple:
        """Static identity of the compiled computation this plan routes
        to: engine + kernel backend + operand structure + resolved
        static capacities."""
        return (self.engine, self.backend, self.batched, self.batch,
                self.a_shape, self.b_shape, self.work_bucket, self.kwargs)


def _sorted_kwargs(kw: dict) -> tuple:
    return tuple(sorted(kw.items()))


def _plan_backend_name(engine: str, backend: str) -> Optional[str]:
    """The backend name a plan for ``engine`` would resolve ``backend``
    to — for quarantine checks *before* the plan is built.  None for
    non-backend-aware engines or unknown requests."""
    spec = _REGISTRY.get(engine)
    if spec is None or not spec.backend_aware:
        return None
    try:
        return kb.resolve_backend(backend).name
    except ValueError:
        return None


def _dequarantine(selected: str, key: str, backend: str,
                  cache: "AutotuneCache") -> tuple[str, bool]:
    """If the selected engine is quarantined for this bucket, walk the
    degradation order to the first healthy engine.  Returns
    (engine, was_remapped)."""
    if not cache.is_quarantined(key, selected,
                                _plan_backend_name(selected, backend)):
        return selected, False
    for eng, _ in DEGRADE_CHAIN:
        if eng != selected and not cache.is_quarantined(
                key, eng, _plan_backend_name(eng, backend)):
            return eng, True
    return selected, False  # everything poisoned: keep the original pick


def _resolve_plan_backend(spec: EngineSpec, backend: str,
                          cached: Optional[str], kw: dict, *,
                          strict: bool = True) -> tuple[Optional[str], dict]:
    """Fold the kernel backend into an engine's plan-time kwargs.

    Backend-aware engines get ``kwargs["backend"] = <resolved name>``
    (cache/autotune outcome beats the "auto" default; an explicit pin
    always wins); other engines carry no backend.  Requesting a pinned
    backend for an explicitly named engine that cannot use one is a
    planning error; under auto selection (``strict=False``) the pin is
    simply irrelevant to a non-aware winner and is dropped.

    A ``cached`` backend name comes from the shared on-disk cache and is
    NOT trusted blindly: an unknown name (version skew, hand-edited
    file) or one that only performs on TPU (an entry recorded on a TPU
    host, replayed on a CPU serving host, would otherwise route every
    multiply through Pallas interpret mode) falls back to the "auto"
    default — a cache hit must never raise or degrade execution."""
    if not spec.backend_aware:
        if backend != "auto" and strict:
            raise ValueError(
                f"engine {spec.name!r} does not take a kernel backend "
                f"(requested {backend!r})")
        return None, kw
    name = None
    if backend == "auto" and cached is not None:
        try:
            bk_c = kb.resolve_backend(cached)
            if kb.on_tpu() or not bk_c.needs_tpu_for_perf:
                name = bk_c.name
        except ValueError:
            pass
    if name is None:
        name = kb.resolve_backend(backend).name
    kw = dict(kw)
    kw["backend"] = name
    return name, kw


def plan(A: CSR, B: CSR, engine: str = "auto", *,
         backend: str = "auto",
         autotune: bool = False,
         cache: Optional[AutotuneCache] = None,
         rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
         model: Any = "auto",
         **kw) -> ExecutionPlan:
    """Select an engine and resolve kwargs for ``A @ B`` without running it.

    engine:  a registered name, or "auto" to select by cached plan /
             heuristic features / measurement.
    backend: kernel-backend request for the stream primitives — a name
             registered in ``kernels/backend.py`` ("xla", "pallas",
             "ref") or "auto".  Resolved HERE, once: the chosen backend
             rides in the plan's kwargs/``jit_key`` and suffixes the
             autotune-cache key, so a pinned backend autotunes its own
             bucket and with "auto" the backend joins the autotune
             search space (e.g. ``spz-fused/xla`` vs
             ``spz-fused/pallas`` per shape bucket).
    autotune: with engine="auto", time every registered engine (and, for
             backend-aware engines, every measurable backend) on this
             input once and cache the winner for the shape/nnz bucket.
    cache:   AutotuneCache override (default: process-wide disk cache).
             Non-default ``rules`` bypass the cache entirely — a cached
             plan from other rules must not shadow the caller's table,
             nor may a custom-rule choice poison the shared cache.
    model:   learned-selection request.  "auto" (default) consults the
             trained dispatch model artifact next to the cache file, if
             one exists; a DispatchModel instance uses it directly;
             False/None disables learned selection.  The model sits
             between cache-hit and measurement in the ladder: a
             confident prediction plans immediately (``source="model"``)
             at ~µs cost, a low-confidence one falls through to
             measurement (``autotune=True``) or heuristics.

    Repeat plans on the *same matrix objects* (the serving steady state)
    are memoized on operand identity and skip selection entirely."""
    if A.n_cols != B.n_rows:
        raise ValueError(f"inner dims differ: {A.shape} @ {B.shape}")
    kb.resolve_backend(backend)  # validate the request up front
    use_cache = rules is DEFAULT_HEURISTICS
    if cache is None:  # NB: `or` would drop an *empty* caller cache
        cache = default_cache()
    memo_extra = None
    if engine == "auto" and use_cache and cache is default_cache():
        try:
            memo_extra = ("plan", backend, autotune, cache.version,
                          _model_token(model, cache), _sorted_kwargs(kw))
            hit = _plan_memo.get(A, B, memo_extra)
            if hit is not None:
                return hit
        except TypeError:  # unhashable kwarg value: skip the memo
            memo_extra = None
    # structural screen sits behind the memo: repeat plans on validated
    # operands (the serving steady state) skip the O(nnz) host checks
    validate_operands(A, B)
    key = cache_key(A, B, backend=backend)
    selected, source, rule, sel_bk = engine, "explicit", None, None
    if engine == "auto":
        hit = cache.get(key) if use_cache else None
        if hit is None and use_cache:
            # pull-on-plan-miss: another process may have measured (or
            # poisoned) this bucket since we loaded the file — one
            # cheap disk read here beats re-measuring or re-crashing
            cache.refresh()
            hit = cache.get(key)
        if hit is not None and cache.is_quarantined(
                key, hit["engine"], hit.get("backend")):
            hit = None  # a poisoned prior selection must not be replayed
        if hit is not None and (hit["source"] == "autotune" or not autotune):
            selected, source = hit["engine"], "cache"
            sel_bk = hit.get("backend")
        else:
            # learned-model step of the ladder: cache miss → ask the
            # trained cost model for an argmin over predicted runtimes.
            # A confident prediction plans right here at ~µs cost; a
            # low-confidence one (or no artifact) falls through to
            # measurement / heuristics exactly as before.
            sel = None
            if use_cache:
                mdl = resolve_model(model, cache)
                sel = _model_select(mdl, extract_features(A, B), key,
                                    backend, cache)
                if sel is not None and not sel.confident:
                    sel = None
            if sel is not None:
                selected, sel_bk, source = sel.engine, sel.backend, "model"
            elif autotune:
                timings: dict[tuple, float] = {}
                for name, bk_name in _measure_candidates(backend):
                    if cache.is_quarantined(key, name, bk_name):
                        continue
                    try:
                        timings[(name, bk_name)] = _measure(
                            get_engine(name), A, B, backend=bk_name)
                    except Exception as e:
                        # a candidate that dies mid-sweep is quarantined
                        # and the sweep continues — one crashing kernel
                        # must not abort measurement of the healthy
                        # candidates
                        cache.quarantine(key, name, bk_name,
                                         reason=f"{type(e).__name__}: {e}")
                if timings:
                    (selected, sel_bk), source = \
                        min(timings, key=timings.get), "autotune"
                    # the winner is the cached plan; the full timing
                    # vector + features are the training dataset the
                    # dispatch model is fitted from offline
                    cache.put(key, selected, "autotune", backend=sel_bk,
                              timings={combo_str(n, b): t
                                       for (n, b), t in timings.items()},
                              features=extract_features(A, B))
                else:  # nothing measurable survived: heuristic fallback
                    selected, rule = choose_engine(extract_features(A, B),
                                                   rules)
                    selected, _ = _dequarantine(selected, key, backend,
                                                cache)
                    source = "heuristic"
            else:
                selected, rule = choose_engine(extract_features(A, B), rules)
                source = "heuristic"
                if use_cache:
                    remapped, was_q = _dequarantine(selected, key, backend,
                                                    cache)
                    if was_q:
                        selected, rule = remapped, "quarantine-fallback"
                    cache.put(key, selected, "heuristic")
    spec = get_engine(selected)
    resolved = _filter_kwargs(spec.fn, kw) if engine == "auto" else kw
    plan_bk, resolved = _resolve_plan_backend(spec, backend, sel_bk,
                                              resolved,
                                              strict=engine != "auto")
    p = ExecutionPlan(engine=selected, batched=False,
                      a_shape=A.shape, b_shape=B.shape,
                      kwargs=_sorted_kwargs(resolved),
                      work_bucket=(_nnz_bucket(A), _nnz_bucket(B)),
                      cache_key=key, source=source, rule=rule,
                      backend=plan_bk)
    if memo_extra is not None:
        _plan_memo.put(A, B, memo_extra, p)
    return p


def execute(p: ExecutionPlan, A: CSR, B: CSR, *,
            return_stats: bool = False):
    """Run a plan against concrete operands.

    The operands must match the planned structure (shapes; the nnz
    bucket may drift within the plan's padding capacities).  A plan made
    once can be executed against every request with matching structure —
    the selection cost is paid at plan time only."""
    if p.batched:
        raise ValueError("batched plan passed to execute(); "
                         "use execute_batched()")
    if A.shape != p.a_shape or B.shape != p.b_shape:
        raise ValueError(
            f"plan/operand mismatch: planned {p.a_shape} @ {p.b_shape}, "
            f"got {A.shape} @ {B.shape}")
    spec = get_engine(p.engine)
    fi.fire("dispatch.execute", engine=p.engine, backend=p.backend)
    out = spec.fn(A, B, **p.kwargs_dict)
    out, stats = out if spec.returns_stats else (out, None)
    out = fi.corrupt("dispatch.execute", out,
                     engine=p.engine, backend=p.backend)
    return (out, stats) if return_stats else out


# ---------------------------------------------------------------------------
# failure policies: deadline + retry + graceful degradation
# ---------------------------------------------------------------------------

# The degradation ladder (the serving analogue of the RISC-V SpGEMM
# fallback-to-scalar path): planned engine/backend first, then the
# device-resident zipper pipeline pinned to the XLA kernel tier, then
# the dense-accumulator reference oracle — slower every step, but each
# step removes a class of failure (autotuned exotic kernels, Pallas
# lowering, vectorized streaming) until only plain per-row accumulation
# remains.
DEGRADE_CHAIN: tuple[tuple[str, Optional[str]], ...] = (
    ("spz-fused", "xla"),
    ("esc", None),
    ("scl-array", None),
)


class CorruptOutput(RuntimeError):
    """An engine returned structurally invalid output (non-finite values
    or out-of-range indices) without raising — e.g. a kernel that
    silently produced garbage.  The resilience layer treats this exactly
    like a crash: retry, then degrade."""


class DeadlineExceeded(RuntimeError):
    """A resilient execution ran past its per-request deadline."""


class ExhaustedFallbacks(RuntimeError):
    """Every tier of the degradation ladder failed; ``report`` carries
    the per-attempt error trail."""

    def __init__(self, message: str, report: "ExecutionReport"):
        self.report = report
        super().__init__(message)


def check_result(out: CSR) -> None:
    """Structural screen of an engine's output: non-finite payloads or
    out-of-range column indices raise :class:`CorruptOutput` so the
    degradation ladder treats silent garbage as a failed attempt rather
    than serving it."""
    indptr = np.asarray(out.indptr)
    nnz = int(indptr[-1])
    if nnz == 0:
        return
    data = np.asarray(out.data)[:nnz]
    if not np.isfinite(data).all():
        raise CorruptOutput(f"non-finite values in output ({nnz} nnz)")
    idx = np.asarray(out.indices)[:nnz]
    if int(idx.min()) < 0 or int(idx.max()) >= out.n_cols:
        raise CorruptOutput(
            f"output column index out of range [0, {out.n_cols})")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Failure policy for the resilient execute path.

    max_attempts:   attempts per tier (first try included).
    backoff_base_s / backoff_factor: deterministic exponential backoff
                    between same-tier retries (no jitter — chaos tests
                    assert exact schedules).
    deadline_s:     total budget measured on ``clock`` from the first
                    attempt; None disables the deadline.
    fallback:       (engine, backend) tiers walked after the planned
                    tier exhausts its retries (``DEGRADE_CHAIN``).
    verify_output:  run :func:`check_result` on every result so silent
                    garbage counts as a failure.
    sleep / clock:  injectable for deterministic tests."""

    max_attempts: int = 3
    backoff_base_s: float = 0.005
    backoff_factor: float = 4.0
    deadline_s: Optional[float] = None
    fallback: tuple = DEGRADE_CHAIN
    verify_output: bool = True
    sleep: Callable[[float], None] = time.sleep
    clock: Callable[[], float] = time.monotonic

    def backoff_s(self, retry: int) -> float:
        """Backoff before retry number ``retry`` (1-based)."""
        return self.backoff_base_s * self.backoff_factor ** (retry - 1)


@dataclasses.dataclass
class ExecutionReport:
    """What actually served a resilient execution: the tier, the attempt
    count, and the error trail that got it there."""

    tier: int                    # 0 = the planned engine/backend
    engine: str
    backend: Optional[str]
    attempts: int                # total attempts across all tiers
    errors: list = dataclasses.field(default_factory=list)
    quarantined: list = dataclasses.field(default_factory=list)

    @property
    def degraded(self) -> bool:
        return self.tier > 0

    @property
    def tier_label(self) -> str:
        if self.tier == 0:
            return "planned"
        bk = f"/{self.backend}" if self.backend else ""
        return f"degraded:{self.engine}{bk}"


def fallback_plan(p: ExecutionPlan, engine: str,
                  backend: Optional[str]) -> ExecutionPlan:
    """Re-target a plan at a degradation tier: same operand structure,
    fallback engine/backend, kwargs re-filtered against the new engine's
    signature."""
    spec = get_engine(engine)
    kw = {k: v for k, v in p.kwargs_dict.items() if k != "backend"}
    kw = _filter_kwargs(spec.fn, kw)
    bk = None
    if spec.backend_aware:
        bk = kb.resolve_backend(backend or "auto").name
        kw["backend"] = bk
    return dataclasses.replace(p, engine=engine, backend=bk,
                               kwargs=_sorted_kwargs(kw),
                               source="fallback", rule=None)


def execute_resilient(p: ExecutionPlan, A: CSR, B: CSR, *,
                      policy: Optional[RetryPolicy] = None,
                      cache: Optional[AutotuneCache] = None,
                      return_stats: bool = False):
    """Run a plan under the failure policy: bounded same-tier retries
    with exponential backoff, a per-request deadline, and graceful
    degradation down :data:`DEGRADE_CHAIN`.

    Returns ``(result, report)`` (or ``((result, stats), report)`` with
    ``return_stats``); the report records which tier actually served.
    A tier that exhausts its retries has its (engine, backend, bucket)
    combo quarantined in the autotune cache so the next plan for this
    bucket does not re-select the crashing kernel.  Raises
    :class:`ExhaustedFallbacks` when every tier fails, or
    :class:`DeadlineExceeded` when the budget runs out first."""
    policy = policy or RetryPolicy()
    if cache is None:
        cache = default_cache()
    start = policy.clock()
    tiers: list[tuple[str, Optional[str]]] = [(p.engine, p.backend)]
    for eng, bk in policy.fallback:
        if (eng, bk) != tiers[0]:
            tiers.append((eng, bk))
    report = ExecutionReport(tier=0, engine=p.engine, backend=p.backend,
                             attempts=0)

    def out_of_time() -> bool:
        return (policy.deadline_s is not None
                and policy.clock() - start >= policy.deadline_s)

    for tier_i, (eng, bk) in enumerate(tiers):
        tp = p if tier_i == 0 else fallback_plan(p, eng, bk)
        report.tier, report.engine, report.backend = tier_i, eng, tp.backend
        for attempt in range(1, policy.max_attempts + 1):
            if out_of_time():
                raise DeadlineExceeded(
                    f"deadline {policy.deadline_s}s exceeded after "
                    f"{report.attempts} attempts "
                    f"(errors: {report.errors})")
            report.attempts += 1
            try:
                out = execute(tp, A, B, return_stats=return_stats)
                if policy.verify_output:
                    check_result(out[0] if return_stats else out)
                return out, report
            except Exception as e:
                report.errors.append(
                    f"{tp.engine}/{tp.backend or '-'}#{attempt}: "
                    f"{type(e).__name__}: {e}")
                if attempt < policy.max_attempts and not out_of_time():
                    policy.sleep(policy.backoff_s(attempt))
        # tier exhausted: poison this combo for the bucket so replanning
        # does not walk straight back into the crashing kernel
        cache.quarantine(p.cache_key, eng, tp.backend,
                         reason=report.errors[-1])
        report.quarantined.append((eng, tp.backend))
    raise ExhaustedFallbacks(
        f"all {len(tiers)} tiers failed after {report.attempts} attempts "
        f"(errors: {report.errors})", report)


def spgemm(A: CSR, B: CSR, engine: str = "auto", *,
           backend: str = "auto",
           autotune: bool = False,
           cache: Optional[AutotuneCache] = None,
           rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
           model: Any = "auto",
           return_stats: bool = False,
           **kw):
    """Multiply two padded CSR matrices through the engine registry.

    Exactly ``execute(plan(A, B, ...), A, B)`` — see :func:`plan` for
    the selection knobs (including the plan-time kernel-backend
    resolution) and :func:`execute` for the run semantics."""
    p = plan(A, B, engine, backend=backend, autotune=autotune, cache=cache,
             rules=rules, model=model, **kw)
    return execute(p, A, B, return_stats=return_stats)


def explain(A: CSR, B: CSR,
            rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS, *,
            backend: str = "auto",
            cache: Optional[AutotuneCache] = None,
            model: Any = "auto") -> dict:
    """Dry-run selection: features + the rule and engine 'auto' would pick
    (ignoring any cached *engine* plan) — for benchmarks and debugging.

    The dict also surfaces the kernel-backend leg of the decision, which
    an ``ExecutionPlan`` resolves but selection output previously hid:

    ``backend``
        the kernel backend a plan for this (engine, request) would run —
        an autotuned backend recorded for the bucket (e.g. the
        ``spz-fused/pallas`` vs ``/xla`` winner) beats the "auto"
        default, exactly as in :func:`plan`; ``None`` for engines that
        take no kernel backend.
    ``rule``
        the heuristic rule that picked the engine.
    ``model``
        the learned-dispatch view of the same request, when a trained
        model resolves: predicted winner, calibrated confidence, whether
        that clears the confidence floor (i.e. whether ``plan()`` would
        take the prediction), per-candidate predicted costs in seconds,
        and the artifact version.  ``None`` when no model is available.
    """
    feats = extract_features(A, B)
    engine, rule = choose_engine(feats, rules)
    key = cache_key(A, B, backend=backend)
    if cache is None:
        cache = default_cache()
    hit = cache.get(key)
    cached_bk = hit.get("backend") if hit else None
    plan_bk, _ = _resolve_plan_backend(get_engine(engine), backend,
                                       cached_bk, {}, strict=False)
    mdl = resolve_model(model, cache)
    sel = _model_select(mdl, feats, key, backend, cache)
    model_info = None
    if sel is not None:
        model_info = {"engine": sel.engine, "backend": sel.backend,
                      "confidence": sel.confidence,
                      "confident": sel.confident,
                      "costs": dict(sel.costs),
                      "version": getattr(mdl, "version", None)}
    return {"engine": engine, "rule": rule, "backend": plan_bk,
            "features": feats, "cache_key": key, "model": model_info}


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

# vmapped unjitted ESC core, jitted once over the whole batch: every lane
# shares the static (cap_products, n_rows, n_cols) plan.
_esc_batched_core = jax.jit(
    jax.vmap(sg.esc_core_impl,
             in_axes=(0, 0, 0, 0, 0, 0, None, None, None)),
    static_argnums=(6, 7, 8))


def _pow2_at_least(n: int) -> int:
    return 1 << max(4, int(n - 1).bit_length())


def _esc_batched(A: BatchedCSR, B: BatchedCSR,
                 cap_products: Optional[int] = None) -> list:
    """One-compilation ESC over a batch: shared power-of-two product
    capacity so ragged batches of similar size reuse the same XLA plan."""
    fi.fire("kernel.batched", engine="esc", lanes=A.batch)
    if cap_products is None:
        works = [int(sg.row_work(a, B[i]).sum()) for i, a in A.lanes()]
        cap_products = _pow2_at_least(max(works + [1]))
    r, c, v, valid, _ = _esc_batched_core(
        A.indptr, A.indices, A.data, B.indptr, B.indices, B.data,
        cap_products, A.n_rows, B.n_cols)
    r, c, v, valid = map(np.asarray, (r, c, v, valid))
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    return [csr_from_coo(r[i][valid[i]], c[i][valid[i]], v[i][valid[i]],
                         (A.n_rows, B.n_cols)) if lane_ok[i] else None
            for i in range(A.batch)]


def _spz_batched(A: BatchedCSR, B: BatchedCSR, *, R: int = 16,
                 S: Optional[int] = None, rsort: bool = False,
                 backend="auto", driver: str = "fused") -> list:
    """Batched SparseZipper driver: rows from *every* valid lane are packed
    into shared lock-step groups of S streams.  The default "fused" driver
    feeds each group through the device-resident expand/sort/merge-tree
    pipeline straight from the stacked BatchedCSR arrays (per-stream lane
    ids index the batch axis); ``driver="host"`` keeps the original
    chunk-at-a-time lock-step loop."""
    S = S or 32 * R
    if driver not in ("fused", "host"):
        raise ValueError(f"unknown spz driver {driver!r}; use 'fused'|'host'")
    fi.fire("kernel.batched", engine="spz", driver=driver, lanes=A.batch)
    bk = kb.resolve_backend(backend)  # unknown names raise, listing all
    stats = sg.SpzStats()
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    valid_lanes = [i for i in range(A.batch) if lane_ok[i]]
    items = [(i, int(r)) for i in valid_lanes for r in range(A.n_rows)]
    # only the host driver walks per-lane numpy copies; the fused driver
    # reads the stacked device arrays directly
    lanes = ({i: (csr_to_numpy(A[i]), csr_to_numpy(B[i]))
              for i in valid_lanes} if driver == "host" else None)
    work = None
    if rsort or driver == "fused":
        work = {i: sg.row_work(A[i], B[i]) for i in valid_lanes}
    if rsort:
        items.sort(key=lambda it: int(work[it[0]][it[1]]))
    out_k = {it: np.empty(0, np.int32) for it in items}
    out_v = {it: np.empty(0, np.float32) for it in items}
    if driver == "fused":
        mats = (A.indptr, A.indices, A.data, B.indptr, B.indices, B.data)
        for g0 in range(0, len(items), S):
            group = items[g0:g0 + S]
            plens = np.array([work[ln][r] for ln, r in group], np.int64)
            sg.fused_process_group(group, plens, mats, R, bk, stats,
                                   out_k, out_v)
    else:
        for g0 in range(0, len(items), S):
            group = items[g0:g0 + S]
            products = []
            for lane, row in group:
                (a_indptr, a_idx, a_val), (b_indptr, b_idx, b_val) = \
                    lanes[lane]
                products.extend(sg.expand_group(
                    [row], a_indptr, a_idx, a_val, b_indptr, b_idx, b_val))
            parts = sg.sort_phase(products, R, len(group), bk, stats,
                                  cap_s=S)
            final = sg.merge_tree_host(parts, R, bk, stats, cap_s=S)
            if final is not None:
                Kf, Vf, lf = final
                for s, it in enumerate(group):
                    out_k[it] = Kf[s, :lf[s]]
                    out_v[it] = Vf[s, :lf[s]]
    results = []
    for i in range(A.batch):
        if not lane_ok[i]:
            results.append(None)
            continue
        rr, cc, vv = [], [], []
        for row in range(A.n_rows):
            k, v = out_k[(i, row)], out_v[(i, row)]
            nz = v != 0.0
            rr.append(np.full(int(nz.sum()), row, np.int64))
            cc.append(k[nz])
            vv.append(v[nz])
        results.append(csr_from_coo(
            np.concatenate(rr) if rr else [],
            np.concatenate(cc) if cc else [],
            np.concatenate(vv) if vv else [], (A.n_rows, B.n_cols)))
    return results


# auto selection for batches maps any single-matrix choice onto the nearest
# batchable engine (the scalar engines have no single-compilation path)
_BATCH_FALLBACK = {"scl-array": "esc", "scl-hash": "esc"}

# batched drivers per engine — every batchable registry entry routes here
_BATCH_DRIVERS: dict[str, Callable] = {
    "esc": _esc_batched,
    "spz": _spz_batched,
    "spz-fused": functools.partial(_spz_batched, driver="fused"),
    "spz-host": functools.partial(_spz_batched, driver="host"),
    "spz-rsort": functools.partial(_spz_batched, rsort=True),
}


def get_batch_driver(name: str) -> Callable:
    """The batched driver callable for a (batchable) engine name — used by
    the lane-sharding layer to run one device group at a time."""
    try:
        return _BATCH_DRIVERS[name]
    except KeyError:
        raise ValueError(f"engine {name!r} has no batched driver") from None


def check_batch(A: BatchedCSR, B: BatchedCSR) -> np.ndarray:
    if A.batch != B.batch or A.n_cols != B.n_rows:
        raise ValueError(f"batch mismatch: {A.batch}x{A.shape} @ "
                         f"{B.batch}x{B.shape}")
    lane_ok = np.asarray(A.valid) & np.asarray(B.valid)
    if not lane_ok.any():
        raise ValueError("no valid lanes in batch")
    return lane_ok


def plan_batched(A: BatchedCSR, B: BatchedCSR, engine: str = "auto", *,
                 backend: str = "auto",
                 cache: Optional[AutotuneCache] = None,
                 rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
                 model: Any = "auto",
                 lane_work_hint: Optional[Sequence[int]] = None,
                 **kw) -> ExecutionPlan:
    """Select a batchable engine and resolve static capacities for a batch.

    engine: "esc", "spz", "spz-rsort", or "auto" (features of the
    heaviest valid lane pick the engine — consulting and feeding the
    same autotune cache as the single-matrix path, keyed on that lane —
    then map onto a batchable one).  The resolved plan carries the
    shared product capacity (esc) or stream geometry (spz) so identical
    request structures reuse one compilation.

    backend: kernel-backend request, resolved at plan time exactly like
    the single-pair :func:`plan` (the spz batch drivers are
    backend-aware; the cache key carries the request).

    lane_work_hint: per-lane total row_work, if the caller already
    computed it (the sharding layer does, for lane balancing) — skips
    the recompute when sizing the esc product capacity."""
    check_batch(A, B)
    kb.resolve_backend(backend)  # validate the request up front
    i_heavy = max((i for i, _ in A.lanes()),
                  key=lambda i: int(np.asarray(A[i].indptr)[-1]))
    key = cache_key(A[i_heavy], B[i_heavy], backend=backend)
    selected, source, rule, sel_bk = engine, "explicit", None, None
    if engine == "auto":
        use_cache = rules is DEFAULT_HEURISTICS
        if cache is None:
            cache = default_cache()
        hit = cache.get(key) if use_cache else None
        if hit is None and use_cache:
            # pull-on-plan-miss (see plan()): pick up selections and
            # quarantines flushed by sibling worker processes
            cache.refresh()
            hit = cache.get(key)
        if hit is not None and cache.is_quarantined(
                key, hit["engine"], hit.get("backend")):
            hit = None  # a poisoned prior selection must not be replayed
        if hit is not None:
            selected, source = hit["engine"], "cache"
            sel_bk = hit.get("backend")
        else:
            # same model step as plan(): a confident learned prediction
            # (on the heaviest lane's features) beats the rules table;
            # the selection flows through _BATCH_FALLBACK below exactly
            # like every other source
            sel = None
            if use_cache:
                mdl = resolve_model(model, cache)
                sel = _model_select(
                    mdl, extract_features(A[i_heavy], B[i_heavy]), key,
                    backend, cache)
                if sel is not None and not sel.confident:
                    sel = None
            if sel is not None:
                selected, sel_bk, source = sel.engine, sel.backend, "model"
            else:
                selected, rule = choose_engine(
                    extract_features(A[i_heavy], B[i_heavy]), rules)
                source = "heuristic"
                if use_cache:
                    remapped_q, was_q = _dequarantine(
                        _BATCH_FALLBACK.get(selected, selected), key,
                        backend, cache)
                    if was_q:
                        selected, rule = remapped_q, "quarantine-fallback"
                    cache.put(key, selected, "heuristic")
    remapped = _BATCH_FALLBACK.get(selected, selected)
    spec = get_engine(remapped)
    if not spec.batchable or remapped not in _BATCH_DRIVERS:
        raise ValueError(f"engine {remapped!r} has no batched path")
    driver = _BATCH_DRIVERS[remapped]
    # auto selection / fallback remap may land on any driver: drop kwargs
    # it can't take (explicitly named engines keep strict kwargs)
    if engine == "auto" or remapped != engine:
        kw = _filter_kwargs(driver, kw)
    if remapped == "esc" and kw.get("cap_products") is None:
        # shared power-of-two product capacity, resolved at plan time so
        # the plan's jit_key fully determines the compiled computation
        works = ([int(w) for w in lane_work_hint]
                 if lane_work_hint is not None else
                 [int(sg.row_work(a, B[i]).sum()) for i, a in A.lanes()])
        kw["cap_products"] = _pow2_at_least(max(works + [1]))
    plan_bk, kw = _resolve_plan_backend(spec, backend, sel_bk, kw,
                                        strict=engine != "auto")
    return ExecutionPlan(engine=remapped, batched=True, batch=A.batch,
                         a_shape=A.shape, b_shape=B.shape,
                         kwargs=_sorted_kwargs(kw),
                         work_bucket=(_nnz_bucket(A[i_heavy]),
                                      _nnz_bucket(B[i_heavy])),
                         cache_key=key, source=source, rule=rule,
                         backend=plan_bk)


def assemble_batched(outs: list, A: BatchedCSR, B: BatchedCSR) -> BatchedCSR:
    """Stack per-lane results (None = invalid lane) into the output
    BatchedCSR whose lane capacity is the max output nnz."""
    empty = csr_from_coo([], [], [], (A.n_rows, B.n_cols))
    cap = max(int(np.asarray(o.indptr)[-1]) for o in outs if o is not None)
    batched = batch_csr([o if o is not None else empty for o in outs],
                        nnz_cap=max(cap, 1))
    return BatchedCSR(batched.indptr, batched.indices, batched.data,
                      jnp.asarray(A.valid) & jnp.asarray(B.valid),
                      batched.shape)


def execute_batched(p: ExecutionPlan, A: BatchedCSR,
                    B: BatchedCSR) -> BatchedCSR:
    """Run a batched plan. Invalid lanes pass through as empty matrices
    with ``valid=False``."""
    if not p.batched:
        raise ValueError("single-pair plan passed to execute_batched(); "
                         "use execute()")
    check_batch(A, B)
    if A.shape != p.a_shape or B.shape != p.b_shape or A.batch != p.batch:
        raise ValueError(
            f"plan/operand mismatch: planned {p.batch}x{p.a_shape} @ "
            f"{p.b_shape}, got {A.batch}x{A.shape} @ {B.shape}")
    fi.fire("dispatch.execute_batched", engine=p.engine, backend=p.backend)
    outs = _BATCH_DRIVERS[p.engine](A, B, **p.kwargs_dict)
    outs = fi.corrupt("dispatch.execute_batched", outs,
                      engine=p.engine, backend=p.backend)
    return assemble_batched(outs, A, B)


def spgemm_batched(A: BatchedCSR, B: BatchedCSR, engine: str = "auto", *,
                   cache: Optional[AutotuneCache] = None,
                   rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
                   model: Any = "auto",
                   **kw) -> BatchedCSR:
    """Multiply a batch of same-shape CSR pairs under one compilation.

    Exactly ``execute_batched(plan_batched(A, B, ...), A, B)``; see
    those for selection and execution semantics."""
    p = plan_batched(A, B, engine, cache=cache, rules=rules, model=model,
                     **kw)
    return execute_batched(p, A, B)


# ---------------------------------------------------------------------------
# compile-ahead plan warming (the serving layer's warm pool)
# ---------------------------------------------------------------------------

_warm_mu = threading.Lock()
_warmed_jit_keys: set = set()
_warm_counters = {"warmed": 0, "hits": 0, "misses": 0}


def note_warmed(jit_key: tuple) -> None:
    """Record a jit identity as compile-warmed in *this* process."""
    with _warm_mu:
        _warmed_jit_keys.add(jit_key)
        _warm_counters["warmed"] += 1


def jit_warmed(jit_key: tuple, count: bool = True) -> bool:
    """Whether ``jit_key`` was compiled ahead of traffic here.

    With ``count=True`` (the serving layer's per-flush check) the
    outcome lands on the warm hit/miss counters."""
    with _warm_mu:
        hit = jit_key in _warmed_jit_keys
        if count:
            _warm_counters["hits" if hit else "misses"] += 1
        return hit


def warm_stats() -> dict:
    """{"warmed": plans compiled ahead, "hits"/"misses": flush checks}."""
    with _warm_mu:
        return dict(_warm_counters)


def reset_warm_stats() -> None:
    with _warm_mu:
        _warmed_jit_keys.clear()
        _warm_counters.update(warmed=0, hits=0, misses=0)


def _synthetic_csr(shape: tuple, nnz_cap: int) -> CSR:
    """Deterministic stand-in operand landing in pad bucket ``nnz_cap``.

    nnz is pinned to ``nnz_cap - 1`` (clamped to the shape's capacity):
    a pad bucket holds nnz in (cap/2, cap], and ``cache_key``'s
    ``bit_length`` bucket puts cap-1 — but not cap itself — in the same
    plan bucket as that dominant range.  Entries spread uniformly with
    strictly increasing columns per row, so the operand is valid CSR
    without any RNG (warming must be deterministic and cheap)."""
    n_rows, n_cols = int(shape[0]), int(shape[1])
    nnz = int(max(1, min(nnz_cap - 1, n_rows * n_cols)))
    base, extra = divmod(nnz, n_rows)
    counts = np.full(n_rows, base, np.int64)
    counts[:extra] += 1
    counts = np.minimum(counts, n_cols)
    rows = np.repeat(np.arange(n_rows), counts)
    cols = (np.concatenate([(np.arange(c) * n_cols) // c
                            for c in counts if c > 0])
            if counts.sum() else np.zeros(0, np.int64))
    vals = np.ones(int(counts.sum()), np.float32)
    return csr_from_coo(rows, cols, vals, (n_rows, n_cols))


def synthetic_bucket_operands(bucket: tuple) -> tuple[CSR, CSR]:
    """A deterministic (A, B) pair whose serving pad bucket is ``bucket``
    (``(A.shape, B.shape, nnz_cap_a, nnz_cap_b)``)."""
    a_shape, b_shape, cap_a, cap_b = bucket
    return _synthetic_csr(a_shape, cap_a), _synthetic_csr(b_shape, cap_b)


def warm_bucket(bucket: tuple, *, engine: str = "auto", max_batch: int = 8,
                cache: Optional[AutotuneCache] = None, mesh=None,
                rules: Sequence[HeuristicRule] = DEFAULT_HEURISTICS,
                sample: Optional[tuple] = None,
                sticky_cap: Optional[int] = None,
                cap_headroom: int = 2) -> dict:
    """Compile one serving pad bucket ahead of its first request.

    Runs a flush-shaped pass — ``batch_csr`` at the bucket's pad
    capacities, ``plan_sharded``, ``execute_sharded`` — over a sampled
    real pair (``sample``) or a synthetic stand-in, so the plan lands in
    the autotune cache *and* the compiled computation lands in this
    process's jit cache before traffic hits the bucket.  The selection
    entry propagates cross-process through the shared cache file; the
    compilation is per-process, which is why coordinator workers run
    their own ``warm`` tasks.

    esc capacity handling: the resulting ``cap_products`` is raised by
    ``cap_headroom`` (a pow2 factor; the sample may not be the bucket's
    heaviest traffic) and by ``sticky_cap`` (the caller's running
    per-bucket max).  The caller seeds its sticky cap from the returned
    ``"cap"`` so real flushes pin to the warmed jit identity instead of
    recompiling at the next capacity boundary.

    Returns ``{"bucket", "engine", "backend", "source", "cap",
    "wall_s"}``."""
    from repro.distributed import spgemm_shard as shard
    if cache is None:
        cache = default_cache()
    _, _, cap_a, cap_b = bucket
    A, B = sample if sample is not None else synthetic_bucket_operands(bucket)
    t0 = time.perf_counter()
    fi.fire("dispatch.warm", bucket=tuple(bucket))
    Ab = batch_csr([A], nnz_cap=cap_a, batch_cap=max_batch)
    Bb = batch_csr([B], nnz_cap=cap_b, batch_cap=max_batch)
    sp = shard.plan_sharded(Ab, Bb, engine, mesh=mesh, cache=cache,
                            rules=rules)
    cap = None
    if sp.base.engine == "esc":
        cap = int(sp.base.kwargs_dict.get("cap_products", 0))
        cap = max(cap * max(int(cap_headroom), 1), int(sticky_cap or 0))
        kwargs = _sorted_kwargs({**sp.base.kwargs_dict,
                                 "cap_products": cap})
        sp = dataclasses.replace(
            sp, base=dataclasses.replace(sp.base, kwargs=kwargs))
    shard.execute_sharded(sp, Ab, Bb)
    note_warmed(sp.base.jit_key)
    return {"bucket": tuple(bucket), "engine": sp.base.engine,
            "backend": sp.base.backend, "source": sp.base.source,
            "cap": cap, "wall_s": time.perf_counter() - t0}
