"""Key-value stream API over the zipper kernels.

A *stream* is a sorted-or-unsorted sequence of (key, value) tuples — in
SpGEMM, the expanded partial products of one output row. The SparseZipper
ISA processes R-wide chunks of up to S streams in lock step (one stream per
matrix-register row). This module provides the chunk-level API (thin
wrappers over kernels/ops.py) plus host-side helpers to marshal ragged
numpy streams into (S, R) chunk fronts and back — the role the indexed
matrix load/store instructions (mlxe.t / msxe.t) play in the paper.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.formats import EMPTY
from repro.kernels import ops


def sort_chunks(keys, vals, lens, *, impl="auto", cap_s=None):
    """mssortk+mssortv over S lock-step streams."""
    return ops.stream_sort(jnp.asarray(keys), jnp.asarray(vals),
                           jnp.asarray(lens), impl=impl, cap_s=cap_s)


def merge_chunks(ka, va, la, kb, vb, lb, *, impl="auto", cap_s=None):
    """mszipk+mszipv over S lock-step streams."""
    return ops.stream_merge(jnp.asarray(ka), jnp.asarray(va), jnp.asarray(la),
                            jnp.asarray(kb), jnp.asarray(vb), jnp.asarray(lb),
                            impl=impl, cap_s=cap_s)


def gather_chunk_fronts(parts_k, parts_v, ptrs, R):
    """Build an (S, R) chunk front from ragged numpy partitions.

    parts_k/parts_v: per-stream numpy arrays; ptrs: per-stream read offsets.
    Returns (keys, vals, lens) numpy arrays — the mlxe.t analogue."""
    S = len(parts_k)
    keys = np.full((S, R), EMPTY, np.int32)
    vals = np.zeros((S, R), np.float32)
    lens = np.zeros(S, np.int32)
    for s in range(S):
        k = parts_k[s]
        p = int(ptrs[s])
        n = min(R, len(k) - p)
        if n > 0:
            keys[s, :n] = k[p:p + n]
            vals[s, :n] = parts_v[s][p:p + n]
            lens[s] = n
    return keys, vals, lens


def scatter_chunk_outputs(out_k, out_v, dst_k, dst_v, dst_ptrs, out_lens):
    """Append per-stream valid outputs to destination buffers — the msxe.t
    analogue. out_k/out_v: (S, W) numpy; dst_*: per-stream numpy buffers."""
    for s in range(len(dst_k)):
        n = int(out_lens[s])
        if n > 0:
            p = int(dst_ptrs[s])
            dst_k[s][p:p + n] = out_k[s, :n]
            dst_v[s][p:p + n] = out_v[s, :n]
            dst_ptrs[s] = p + n
