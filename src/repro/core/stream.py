"""Key-value stream API over the zipper kernels.

A *stream* is a sorted-or-unsorted sequence of (key, value) tuples — in
SpGEMM, the expanded partial products of one output row. The SparseZipper
ISA processes R-wide chunks of up to S streams in lock step (one stream per
matrix-register row). This module provides the chunk-level API (thin
wrappers over kernels/ops.py) plus host-side helpers to marshal ragged
numpy streams into (S, R) chunk fronts and back — the role the indexed
matrix load/store instructions (mlxe.t / msxe.t) play in the paper.

Two tiers coexist:

  * the **host tier** (``sort_chunks``/``merge_chunks`` + the numpy
    gather/scatter helpers) drives one kernel issue at a time from Python
    — stats-faithful to the paper's per-instruction accounting, but every
    chunk pays a dispatch;
  * the **device tier** (``merge_partitions``/``fused_sort_merge``) keeps
    the stream state — read/write pointers and the whole lock-step merge
    tree — resident on the device: one jitted computation per (S, L, R)
    bucket, with the data-dependent advancement under
    ``jax.lax.while_loop``.  Instruction counters come back as device
    scalars so ``SpzStats`` stays exact.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.formats import EMPTY
from repro.kernels import backend as kb
from repro.kernels import merge_tree, ops


def sort_chunks(keys, vals, lens, *, backend="auto", cap_s=None):
    """mssortk+mssortv over S lock-step streams."""
    return ops.stream_sort(jnp.asarray(keys), jnp.asarray(vals),
                           jnp.asarray(lens), backend=backend, cap_s=cap_s)


def merge_chunks(ka, va, la, kb_, vb, lb, *, backend="auto", cap_s=None):
    """mszipk+mszipv over S lock-step streams."""
    return ops.stream_merge(jnp.asarray(ka), jnp.asarray(va), jnp.asarray(la),
                            jnp.asarray(kb_), jnp.asarray(vb),
                            jnp.asarray(lb), backend=backend, cap_s=cap_s)


def merge_partitions(ka, va, la, kb_, vb, lb, *, R=16, pair_streams=None,
                     with_counters=True, backend="auto"):
    """Device-resident full merge of two padded (N, L) partitions: the
    lock-step chunk advancement (pointers, copy-through tails) runs under
    one ``jax.lax.while_loop`` instead of a host loop of mszip issues.
    Returns (keys, vals, lens, MergeCounters)."""
    return ops.merge_partitions(jnp.asarray(ka), jnp.asarray(va),
                                jnp.asarray(la), jnp.asarray(kb_),
                                jnp.asarray(vb), jnp.asarray(lb),
                                R=R, pair_streams=pair_streams,
                                with_counters=with_counters, backend=backend)


def chunk_sort_partitions(keys, vals, plens, *, R, backend="auto"):
    """Chunk-sort (S, L) padded streams into (S, C, R) sorted partitions.

    Traceable device replacement for the host ``sort_phase``: all S*C
    R-chunks are sorted in ONE kernel issue — the registry backend's
    ``chunk_sort`` primitive (scatter-free linear sort on ``xla``, the
    native Pallas chunk-sort kernel on ``pallas``; bit-identical) — but
    the returned counters keep the host accounting (one mssort per chunk
    column that holds any data — ceil(max plens / R) issues, each a load
    + store).

    Returns (keys (S, C, R), vals, lens (S, C), n_mssort, sort_elems).
    """
    S, L = keys.shape
    C = L // R
    assert C * R == L, f"partition width {L} must be a multiple of R={R}"
    plens = plens.astype(jnp.int32)
    chunk_lens = jnp.clip(plens[:, None]
                          - jnp.arange(C, dtype=jnp.int32)[None, :] * R,
                          0, R).reshape(S * C)
    bk = kb.resolve_backend(backend)
    sk, sv, sl = bk.chunk_sort(keys.reshape(S * C, R),
                               vals.reshape(S * C, R), chunk_lens)
    n_mssort = -(-jnp.max(plens) // R)
    sort_elems = jnp.sum(plens, dtype=jnp.int32)
    return (sk.reshape(S, C, R), sv.reshape(S, C, R), sl.reshape(S, C),
            n_mssort.astype(jnp.int32), sort_elems)


def fused_sort_merge(keys, vals, plens, *, R, backend="auto",
                     with_counters=True, detailed=False):
    """Device-resident sort + zip-merge tree over padded product streams.

    keys/vals: (S, L) unsorted partial products (EMPTY padded), L = C*R
    with C a power of two; plens: (S,) valid lengths.  Backends that
    provide the whole-pipeline ``fused_bucket`` kernel (pallas) run sort
    + the entire merge tree as ONE kernel issue with the partitions
    resident in VMEM across rounds; otherwise the pipeline composes the
    backend's ``chunk_sort`` with the XLA merge tree
    (``merge_tree.zip_merge_tree``).  Both routes are bit-identical.
    Returns (keys (S, L), vals, lens (S,), counters (6,) int32:
    [n_mssort, sort_elems, n_mszip, zip_elems, chunk_loads,
    chunk_stores]) with the host driver's instruction accounting (zeros
    for the merge counters when ``with_counters=False`` skips the
    pointer state machine).

    ``detailed=True`` instead returns the per-(round, pair) merge
    counters from ``merge_tree.zip_merge_tree`` in place of the 6-vector
    — the form the bucketed spz driver needs to rebuild lock-step-group
    counts across split kernel calls (the sort-phase counters are
    plens-derivable, so they are omitted there).
    """
    bk = kb.resolve_backend(backend)
    if bk.fused_bucket is not None:
        return bk.fused_bucket(keys, vals, plens.astype(jnp.int32), R=R,
                               with_counters=with_counters,
                               detailed=detailed)
    sk, sv, sl, n_mssort, sort_elems = chunk_sort_partitions(
        keys, vals, plens, R=R, backend=bk)
    if detailed:
        return merge_tree.zip_merge_tree(sk, sv, sl, R=R, detailed=True)
    mk, mv, ml, zc = merge_tree.zip_merge_tree(sk, sv, sl, R=R,
                                               with_counters=with_counters)
    counters = jnp.stack([
        n_mssort, sort_elems, zc.n_mszip, zc.zip_elems,
        n_mssort + zc.chunk_loads, n_mssort + zc.chunk_stores,
    ])
    return mk, mv, ml, counters


def gather_chunk_fronts(parts_k, parts_v, ptrs, R):
    """Build an (S, R) chunk front from ragged numpy partitions.

    parts_k/parts_v: per-stream numpy arrays; ptrs: per-stream read offsets.
    Returns (keys, vals, lens) numpy arrays — the mlxe.t analogue."""
    S = len(parts_k)
    keys = np.full((S, R), EMPTY, np.int32)
    vals = np.zeros((S, R), np.float32)
    lens = np.zeros(S, np.int32)
    for s in range(S):
        k = parts_k[s]
        p = int(ptrs[s])
        n = min(R, len(k) - p)
        if n > 0:
            keys[s, :n] = k[p:p + n]
            vals[s, :n] = parts_v[s][p:p + n]
            lens[s] = n
    return keys, vals, lens


def scatter_chunk_outputs(out_k, out_v, dst_k, dst_v, dst_ptrs, out_lens):
    """Append per-stream valid outputs to destination buffers — the msxe.t
    analogue. out_k/out_v: (S, W) numpy; dst_*: per-stream numpy buffers."""
    for s in range(len(dst_k)):
        n = int(out_lens[s])
        if n > 0:
            p = int(dst_ptrs[s])
            dst_k[s][p:p + n] = out_k[s, :n]
            dst_v[s][p:p + n] = out_v[s, :n]
            dst_ptrs[s] = p + n
