"""Core SpGEMM substrate: formats, engines, and the plan/execute dispatch.

The canonical multiply entry point is ``repro.core.spgemm`` — the
dispatch-layer function (``spgemm(A, B, engine="auto")``).  The engines
*module* ``repro.core.spgemm`` (``work_stats``, ``spgemm_esc``,
``spgemm_spz``, ...) stays importable under the stable alias
``repro.core.spgemm_engines``; import order below matters — the alias
must bind before ``dispatch.spgemm`` shadows the submodule name on the
package.  The old ``spgemm_engines.spgemm(method=...)`` entry is a
deprecated thin delegate to the dispatch layer.
"""
# 1) bind the engines module under its collision-free alias (this also
#    loads the submodule, so `from repro.core.spgemm import X` keeps
#    working everywhere)
from repro.core import spgemm as spgemm_engines
# 2) re-export the dispatch layer; `spgemm` (the function) intentionally
#    shadows the submodule attribute from here on
from repro.core.dispatch import (AutotuneCache, ExecutionPlan, available_engines,
                                 execute, execute_batched, explain, plan,
                                 plan_batched, register_engine, spgemm,
                                 spgemm_batched)
from repro.core.formats import BatchedCSR, CSR, batch_csr, random_sparse

__all__ = [
    "AutotuneCache", "BatchedCSR", "CSR", "ExecutionPlan",
    "available_engines", "batch_csr", "execute", "execute_batched",
    "explain", "plan", "plan_batched", "random_sparse", "register_engine",
    "spgemm", "spgemm_batched", "spgemm_engines",
]
