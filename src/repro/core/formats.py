"""Static-shape sparse matrix formats for JAX.

JAX requires static shapes, so all sparse containers here are *padded*:
``indices``/``data`` arrays have a fixed capacity ``nnz_cap`` and rows are
delimited by ``indptr`` exactly as in classic CSR. Padding slots carry the
sentinel key ``EMPTY`` (INT32_MAX) so they sort to the end of any key-value
stream — the same trick SparseZipper uses to tag invalid/duplicate keys
flowing through the systolic array.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Sentinel key: sorts after every valid column index.
EMPTY = np.int32(np.iinfo(np.int32).max)


class InvalidOperand(ValueError):
    """Structured rejection of a malformed sparse operand.

    Raised at the service/dispatch boundary instead of letting a
    non-monotonic ``indptr`` or out-of-range column index flow into a
    kernel, where it produces garbage output or an opaque XLA crash.
    ``field`` names the offending piece (e.g. ``"A.indptr"``)."""

    def __init__(self, field: str, message: str):
        self.field = field
        super().__init__(f"{field}: {message}")


def validate_csr(m: "CSR", name: str = "operand") -> None:
    """Screen a padded CSR for structural corruption; raise
    :class:`InvalidOperand` naming the bad field, or return None.

    Checks (in order): field dtypes, indptr shape/monotonicity/range
    against ``nnz_cap``, column indices within ``[0, n_cols)`` over the
    valid region, and finite values.  Cost is O(nnz) host work — paid
    once per request at the intake boundary, not per plan/execute."""
    if len(m.shape) != 2 or m.shape[0] < 1 or m.shape[1] < 1:
        raise InvalidOperand(f"{name}.shape", f"not a matrix shape: {m.shape}")
    indptr = np.asarray(m.indptr)
    if indptr.dtype.kind not in "iu":
        raise InvalidOperand(f"{name}.indptr",
                             f"expected integer dtype, got {indptr.dtype}")
    if indptr.ndim != 1 or indptr.shape[0] != m.n_rows + 1:
        raise InvalidOperand(
            f"{name}.indptr",
            f"expected shape ({m.n_rows + 1},), got {indptr.shape}")
    if int(indptr[0]) != 0:
        raise InvalidOperand(f"{name}.indptr",
                             f"must start at 0, got {int(indptr[0])}")
    if (np.diff(indptr) < 0).any():
        drop = int(np.argmax(np.diff(indptr) < 0))
        raise InvalidOperand(f"{name}.indptr",
                             f"non-monotonic at row {drop}")
    indices = np.asarray(m.indices)
    if indices.dtype.kind not in "iu":
        raise InvalidOperand(f"{name}.indices",
                             f"expected integer dtype, got {indices.dtype}")
    data = np.asarray(m.data)
    if data.dtype.kind != "f":
        raise InvalidOperand(f"{name}.data",
                             f"expected floating dtype, got {data.dtype}")
    if indices.shape != data.shape or indices.ndim != 1:
        raise InvalidOperand(
            f"{name}.indices",
            f"indices/data capacity mismatch: {indices.shape} vs {data.shape}")
    nnz = int(indptr[-1])
    if nnz > m.nnz_cap:
        raise InvalidOperand(f"{name}.indptr",
                             f"nnz {nnz} exceeds capacity {m.nnz_cap}")
    live_idx = indices[:nnz]
    if nnz and (int(live_idx.min()) < 0 or int(live_idx.max()) >= m.n_cols):
        bad = int(live_idx[(live_idx < 0) | (live_idx >= m.n_cols)][0])
        raise InvalidOperand(f"{name}.indices",
                             f"column {bad} out of range [0, {m.n_cols})")
    if nnz and not np.isfinite(data[:nnz]).all():
        raise InvalidOperand(f"{name}.data", "non-finite value in payload")


def validate_operands(A: "CSR", B: "CSR") -> None:
    """Validate both sides of a multiply (see :func:`validate_csr`)."""
    validate_csr(A, "A")
    validate_csr(B, "B")
    if A.n_cols != B.n_rows:
        raise InvalidOperand("B.shape",
                             f"inner dims differ: {A.shape} @ {B.shape}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CSR:
    """Padded CSR matrix. ``indptr``: (n_rows+1,) int32; ``indices``/``data``:
    (nnz_cap,) with valid entries in [indptr[0], indptr[n_rows]) and padding
    (= EMPTY / 0) afterwards."""

    indptr: jnp.ndarray
    indices: jnp.ndarray
    data: jnp.ndarray
    shape: Tuple[int, int]

    # -- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (self.indptr, self.indices, self.data), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    # -- properties ------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz_cap(self) -> int:
        return int(self.indices.shape[0])

    def nnz(self):
        return self.indptr[-1]

    def row_lengths(self):
        return self.indptr[1:] - self.indptr[:-1]

    # -- conversions -----------------------------------------------------
    def to_dense(self) -> jnp.ndarray:
        n_rows, n_cols = self.shape
        rows = row_ids_from_indptr(self.indptr, self.nnz_cap)
        valid = jnp.arange(self.nnz_cap) < self.indptr[-1]
        r = jnp.where(valid, rows, 0)
        c = jnp.where(valid, self.indices, 0)
        v = jnp.where(valid, self.data, 0.0)
        out = jnp.zeros((n_rows, n_cols), self.data.dtype)
        return out.at[r, c].add(v)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BatchedCSR:
    """A batch of same-shape CSR matrices with one shared static capacity.

    All lanes share ``shape`` and ``nnz_cap`` so the whole batch lowers to
    three dense arrays — the layout the batched SpGEMM engines compile once
    for and reuse across requests:

      ``indptr``  (batch, n_rows+1) int32
      ``indices`` (batch, nnz_cap)  int32, padding = EMPTY
      ``data``    (batch, nnz_cap)  float, padding = 0
      ``valid``   (batch,)          bool — lane validity mask; padding lanes
                  (added to round a ragged batch up to a fixed batch size)
                  hold empty matrices and must be ignored by consumers.
    """

    indptr: jnp.ndarray
    indices: jnp.ndarray
    data: jnp.ndarray
    valid: jnp.ndarray
    shape: Tuple[int, int]

    # -- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (self.indptr, self.indices, self.data, self.valid), self.shape

    @classmethod
    def tree_unflatten(cls, shape, leaves):
        return cls(*leaves, shape=shape)

    # -- properties ------------------------------------------------------
    @property
    def batch(self) -> int:
        return int(self.indptr.shape[0])

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz_cap(self) -> int:
        return int(self.indices.shape[1])

    @property
    def n_valid(self) -> int:
        return int(np.asarray(self.valid).sum())

    def __len__(self) -> int:
        return self.batch

    def __getitem__(self, i: int) -> CSR:
        """Extract lane ``i`` as a standalone CSR (shared capacity kept)."""
        return CSR(self.indptr[i], self.indices[i], self.data[i], self.shape)

    def lanes(self):
        """Iterate (index, CSR) over valid lanes only."""
        valid = np.asarray(self.valid)
        for i in range(self.batch):
            if valid[i]:
                yield i, self[i]


def batch_csr(mats, nnz_cap: int | None = None,
              batch_cap: int | None = None) -> BatchedCSR:
    """Stack same-shape CSR matrices into a BatchedCSR.

    ``nnz_cap``/``batch_cap`` pad capacity/lane-count up to fixed sizes so
    ragged request batches reuse one compiled kernel; defaults are the
    batch maxima (no padding lanes)."""
    if not mats:
        raise ValueError("batch_csr needs at least one matrix")
    shape = mats[0].shape
    for m in mats:
        if m.shape != shape:
            raise ValueError(f"shape mismatch in batch: {m.shape} != {shape}")
    nnzs = [int(np.asarray(m.indptr)[-1]) for m in mats]
    cap = nnz_cap if nnz_cap is not None else max(max(nnzs), 1)
    if cap < max(nnzs):
        raise ValueError(f"nnz_cap {cap} < batch max nnz {max(nnzs)}")
    bcap = batch_cap if batch_cap is not None else len(mats)
    if bcap < len(mats):
        raise ValueError(f"batch_cap {bcap} < batch size {len(mats)}")
    indptr = np.zeros((bcap, shape[0] + 1), np.int32)
    indices = np.full((bcap, cap), EMPTY, np.int32)
    data = np.zeros((bcap, cap), np.float32)
    valid = np.zeros(bcap, bool)
    for i, m in enumerate(mats):
        indptr[i] = np.asarray(m.indptr)
        indices[i, :nnzs[i]] = np.asarray(m.indices)[:nnzs[i]]
        data[i, :nnzs[i]] = np.asarray(m.data)[:nnzs[i]]
        valid[i] = True
    return BatchedCSR(jnp.asarray(indptr), jnp.asarray(indices),
                      jnp.asarray(data), jnp.asarray(valid), shape)


def unbatch_csr(b: BatchedCSR):
    """Valid lanes of a BatchedCSR as a list of CSR matrices."""
    return [m for _, m in b.lanes()]


def row_ids_from_indptr(indptr: jnp.ndarray, cap: int) -> jnp.ndarray:
    """Expand CSR indptr into per-entry row ids (length ``cap``)."""
    n_rows = indptr.shape[0] - 1
    # row id of entry e = number of row starts <= e, minus 1
    e = jnp.arange(cap, dtype=indptr.dtype)
    return jnp.searchsorted(indptr[1:], e, side="right").astype(jnp.int32).clip(0, n_rows - 1)


def csr_from_dense(dense, nnz_cap: int | None = None) -> CSR:
    """Build a padded CSR from a dense numpy/jnp array (host-side)."""
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    r, c = np.nonzero(dense)
    v = dense[r, c]
    nnz = len(r)
    cap = nnz_cap if nnz_cap is not None else max(nnz, 1)
    assert cap >= nnz, f"nnz_cap {cap} < nnz {nnz}"
    indptr = np.zeros(n_rows + 1, np.int32)
    np.add.at(indptr[1:], r, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    indices = np.full(cap, EMPTY, np.int32)
    data = np.zeros(cap, dense.dtype if dense.dtype.kind == "f" else np.float32)
    indices[:nnz] = c
    data[:nnz] = v
    return CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data), (n_rows, n_cols))


def csr_from_coo(rows, cols, vals, shape, nnz_cap: int | None = None) -> CSR:
    """Host-side COO→CSR (rows need not be sorted; duplicates are summed)."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    vals = np.asarray(vals)
    key = rows * shape[1] + cols
    order = np.argsort(key, kind="stable")
    key, rows, cols, vals = key[order], rows[order], cols[order], vals[order]
    if len(key):
        uniq, inv = np.unique(key, return_inverse=True)
        acc = np.zeros(len(uniq), vals.dtype)
        np.add.at(acc, inv, vals)
        rows = (uniq // shape[1]).astype(np.int32)
        cols = (uniq % shape[1]).astype(np.int32)
        vals = acc
    nnz = len(rows)
    cap = nnz_cap if nnz_cap is not None else max(nnz, 1)
    indptr = np.zeros(shape[0] + 1, np.int32)
    np.add.at(indptr[1:], rows, 1)
    indptr = np.cumsum(indptr).astype(np.int32)
    indices = np.full(cap, EMPTY, np.int32)
    data = np.zeros(cap, np.float32)
    indices[:nnz] = cols
    data[:nnz] = vals.astype(np.float32)
    return CSR(jnp.asarray(indptr), jnp.asarray(indices), jnp.asarray(data), shape)


def random_sparse(n_rows: int, n_cols: int, density: float, *, seed: int = 0,
                  pattern: str = "uniform", skew: float = 1.5) -> CSR:
    """Synthetic sparse matrices with controllable structure.

    pattern:
      uniform   — iid Bernoulli(density)
      powerlaw  — Zipf-distributed row degrees (graph-like, high work variance)
      banded    — nonzeros near the diagonal (scientific-simulation-like)
      blocked   — random dense blocks (mesh/FEM-like)
    """
    rng = np.random.default_rng(seed)
    target_nnz = max(1, int(n_rows * n_cols * density))
    if pattern == "uniform":
        rows = rng.integers(0, n_rows, target_nnz)
        cols = rng.integers(0, n_cols, target_nnz)
    elif pattern == "powerlaw":
        deg = rng.zipf(skew, n_rows).astype(np.int64)
        deg = np.minimum(deg * max(1, target_nnz // max(1, deg.sum())), n_cols // 2 + 1)
        # rescale to target nnz
        scale = target_nnz / max(1, deg.sum())
        deg = np.maximum(1, (deg * scale).astype(np.int64))
        rows = np.repeat(np.arange(n_rows), deg)
        cols = rng.integers(0, n_cols, len(rows))
    elif pattern == "banded":
        bw = max(2, int(density * n_cols * 4))
        rows = rng.integers(0, n_rows, target_nnz)
        offs = rng.integers(-bw, bw + 1, target_nnz)
        cols = np.clip(rows * n_cols // n_rows + offs, 0, n_cols - 1)
    elif pattern == "blocked":
        bs = 8
        nb = max(1, target_nnz // (bs * bs))
        br = rng.integers(0, max(1, n_rows - bs), nb)
        bc = rng.integers(0, max(1, n_cols - bs), nb)
        rr = br[:, None, None] + np.arange(bs)[None, :, None]
        cc = bc[:, None, None] + np.arange(bs)[None, None, :]
        rows = np.broadcast_to(rr, (nb, bs, bs)).reshape(-1)
        cols = np.broadcast_to(cc, (nb, bs, bs)).reshape(-1)
    else:
        raise ValueError(f"unknown pattern {pattern}")
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return csr_from_coo(rows, cols, vals, (n_rows, n_cols))


def csr_to_numpy(m: CSR):
    """Return (indptr, indices, data) as numpy, truncated to true nnz."""
    indptr = np.asarray(m.indptr)
    nnz = int(indptr[-1])
    return indptr, np.asarray(m.indices)[:nnz], np.asarray(m.data)[:nnz]
