"""Sharded data pipeline.

Deterministic synthetic token streams (seeded per (shard, step) so any
worker can regenerate any batch — the property that makes checkpoint/resume
and elastic re-sharding trivial), background prefetch, and straggler
mitigation via a deadline + backup-fetch policy (the data-side analogue of
backup tasks; on one host the "remote fetch" is simulated but the policy
code is real and unit-tested).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class TokenDataset:
    """Deterministic synthetic LM token stream with skip-to-step resume."""

    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, n_shards: int = 1, shard_id: int = 0,
                 enc_tokens: int = 0, d_model: int = 0):
        assert global_batch % n_shards == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.batch = global_batch // n_shards
        self.seed = seed
        self.n_shards = n_shards
        self.shard_id = shard_id
        self.enc_tokens = enc_tokens
        self.d_model = d_model

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.shard_id)
        # markovian-ish stream: token depends on previous via mixing, so the
        # model has learnable structure (examples show loss decreasing)
        base = rng.integers(0, self.vocab_size,
                            (self.batch, self.seq_len + 1), np.int32)
        mixed = base.copy()
        mixed[:, 1:] = (base[:, 1:] + 3 * base[:, :-1]) % self.vocab_size
        out = {"tokens": mixed[:, :-1], "labels": mixed[:, 1:]}
        if self.enc_tokens:
            out["enc_inp"] = rng.standard_normal(
                (self.batch, self.enc_tokens, self.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch with straggler mitigation.

    Each fetch has a soft deadline; if the primary fetch misses it, a backup
    fetch for the same step is issued (fetches are deterministic, so
    whichever finishes first wins — duplicate work, never duplicate data)."""

    def __init__(self, dataset: TokenDataset, *, depth: int = 2,
                 deadline_s: float = 5.0,
                 fetch_fn: Optional[Callable[[int], dict]] = None):
        self.ds = dataset
        self.depth = depth
        self.deadline_s = deadline_s
        self.fetch_fn = fetch_fn or dataset.batch_at
        self.backup_fetches = 0
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = 0
        self._thread: Optional[threading.Thread] = None

    def start(self, step: int = 0):
        self._step = step
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _fetch_with_backup(self, step: int) -> dict:
        result: dict = {}
        done = threading.Event()

        def attempt():
            try:
                r = self.fetch_fn(step)
                if not done.is_set():
                    result.update(r)
                    done.set()
            except Exception:  # pragma: no cover - defensive
                pass

        t1 = threading.Thread(target=attempt, daemon=True)
        t1.start()
        if not done.wait(self.deadline_s):
            # primary missed the deadline: issue a backup fetch
            self.backup_fetches += 1
            t2 = threading.Thread(target=attempt, daemon=True)
            t2.start()
            done.wait()
        return result

    def _run(self):
        while not self._stop.is_set():
            batch = self._fetch_with_backup(self._step)
            batch["_step"] = self._step
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            self._step += 1

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2)
