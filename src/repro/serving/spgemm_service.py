"""Continuous SpGEMM serving: request queue -> bucketed lanes -> sharded plan.

The dispatch layer's caches only pay off under a *stream* of requests —
the ROADMAP's "production traffic" direction.  This service closes that
loop: callers ``submit`` CSR pairs of mixed shapes/densities; requests
are queued per **pad bucket** (operand shapes + power-of-two nnz
bounds), so every flush of a bucket builds ``BatchedCSR`` lanes with
identical array shapes and lands on one already-compiled computation; a
bucket flushes when it reaches ``max_batch`` lanes or its oldest
request ages past ``flush_timeout``.  Execution goes through the
work-balanced sharded plan path (``distributed/spgemm_shard.py``), and
every flush records its plan provenance — after warmup, selections come
from the autotune cache and the plan hit rate approaches 1.

**Failure model** (the resilience layer of PR 6): operands are
structurally validated at the ``submit`` boundary
(:class:`~repro.core.formats.InvalidOperand` names the bad field); each
flush runs under a supervisor that retries the planned tier with
exponential backoff, walks the degradation ladder
(``core/dispatch.py::DEGRADE_CHAIN``) when the planned kernel keeps
failing — quarantining the poisoned (engine, backend, bucket) combo in
the autotune cache — and finally *isolates* per request on the
dense-accumulator reference engine, so one poisoned request dead-letters
alone instead of failing its whole co-bucketed batch.  Shard-worker loss
mid-flush is recovered one layer down (``_execute_groups``'s supervisor
re-runs the dead worker's lanes on a survivor, bit-identical).  Every
request resolves: ``result`` on success, or a structured
:class:`SpgemmError` on the dead-letter queue.  Per-request deadlines
(``policy.deadline_s``, measured on the service clock from submission)
bound how long a request may be retried before it is dead-lettered.

The clock is injectable (and ``submit``/``pump`` take an explicit
``now``) so tests and benchmarks can drive deterministic virtual
traffic; the CLI (``launch/serve_spgemm.py``) and the ``serve``
benchmark section use it against the wall clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core import dispatch as dp
from repro.core.formats import CSR, batch_csr, validate_operands
from repro.distributed import spgemm_shard as shard
from repro.runtime import faultinject as fi


def _pow2_bucket(n: int) -> int:
    """Power-of-two pad bound >= n (min 16): the nnz capacity every
    request in a bucket is padded to, so one compiled computation serves
    the whole bucket."""
    return 1 << max(4, int(max(int(n), 1) - 1).bit_length())


def bucket_key(A: CSR, B: CSR) -> tuple:
    """(A.shape, B.shape, pad bucket of A.nnz, pad bucket of B.nnz)."""
    nnz_a = int(np.asarray(A.indptr)[-1])
    nnz_b = int(np.asarray(B.indptr)[-1])
    return (A.shape, B.shape, _pow2_bucket(nnz_a), _pow2_bucket(nnz_b))


@dataclasses.dataclass
class SpgemmError:
    """Structured failure result for one request (the dead-letter
    payload): where it failed, why, and after how many attempts."""

    id: int
    bucket: tuple
    stage: str        # "flush" | "isolate" | "deadline"
    kind: str         # exception class name ("DeadlineExceeded", ...)
    message: str
    attempts: int
    t: float

    def __str__(self) -> str:
        return (f"SpgemmError(request {self.id} @ {self.stage}: "
                f"{self.kind}: {self.message})")


@dataclasses.dataclass
class SpGemmRequest:
    """One queued multiply; exactly one of ``result`` / ``error`` lands
    when its bucket flushes (or its deadline expires)."""

    A: CSR
    B: CSR
    id: int
    t_submit: float
    bucket: tuple
    result: Optional[CSR] = None
    error: Optional[SpgemmError] = None
    t_done: Optional[float] = None
    engine: Optional[str] = None
    tier: Optional[str] = None   # "planned" | "degraded:..." | "isolated"

    @property
    def done(self) -> bool:
        return self.result is not None or self.error is not None

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def latency(self) -> float:
        if self.t_done is None:
            raise ValueError(f"request {self.id} not finished")
        return self.t_done - self.t_submit


@dataclasses.dataclass
class FlushRecord:
    """Per-flush provenance: which bucket ran, on what plan, why, and —
    under failure — which tier actually served and at what cost."""

    bucket: tuple
    n_requests: int
    engine: str
    source: str        # "cache" = selection served from the autotune cache
    reason: str        # "full" | "timeout" | "drain"
    t: float
    wall_s: float      # host wall-clock spent executing the flush
    tier: str = "planned"   # "planned" | "degraded:<engine>" | "isolated"
    attempts: int = 1       # execution attempts across tiers
    n_failed: int = 0       # requests dead-lettered by this flush
    errors: tuple = ()      # per-attempt error trail (str)

    @property
    def plan_hit(self) -> bool:
        return self.source == "cache"

    @property
    def degraded(self) -> bool:
        return self.tier != "planned"


class SpGemmService:
    """Batched continuous serving over the plan/execute dispatch stack.

    max_batch:     lanes per flush (also the BatchedCSR batch_cap, so
                   every flush of a bucket compiles to the same shapes).
    flush_timeout: seconds a bucket may age before ``pump`` flushes it
                   partially filled.
    engine/rules/cache: forwarded to planning (``plan_sharded``).
    mesh:          lane mesh for sharded execution (default: all devices).
    clock:         time source for submit/done stamps (injectable).
    policy:        :class:`~repro.core.dispatch.RetryPolicy` governing
                   per-flush retries, backoff, the degradation ladder,
                   and the per-request deadline (``deadline_s``, taken
                   against this service's clock).
    coordinator:   a :class:`~repro.runtime.coordinator.
                   ProcessCoordinator` — when set, flushes are
                   *dispatched* to its worker processes instead of run
                   inline: ``_flush`` submits a packed task and returns
                   immediately, ``pump``/``drain`` collect finished
                   tasks, and concurrent buckets overlap across worker
                   processes.  Worker death mid-flush is recovered by
                   the coordinator (re-run on a survivor); when the
                   whole pool is lost, the affected requests fall back
                   to this process's own in-process ladder — every
                   submitted id still resolves."""

    def __init__(self, *, max_batch: int = 8, flush_timeout: float = 0.02,
                 engine: str = "auto",
                 mesh=None,
                 cache: Optional[dp.AutotuneCache] = None,
                 rules=dp.DEFAULT_HEURISTICS,
                 clock: Callable[[], float] = time.monotonic,
                 policy: Optional[dp.RetryPolicy] = None,
                 coordinator=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.flush_timeout = flush_timeout
        self.engine = engine
        self.mesh = mesh
        self.cache = cache if cache is not None else dp.default_cache()
        self.rules = rules
        self.clock = clock
        self.policy = policy if policy is not None else dp.RetryPolicy()
        self.coordinator = coordinator
        self._queues: dict[tuple, list[SpGemmRequest]] = {}
        self._opened: dict[tuple, float] = {}
        self._bucket_caps: dict[tuple, int] = {}
        self._next_id = 0
        self._by_id: dict[int, SpGemmRequest] = {}
        # task_id -> (bucket key, requests, reason, t_flush, t0_wall)
        self._inflight: dict[int, tuple] = {}
        self.completed: list[SpGemmRequest] = []
        self.dead_letters: list[SpGemmRequest] = []
        self.flush_log: list[FlushRecord] = []

    # -- intake ----------------------------------------------------------

    def submit(self, A: CSR, B: CSR,
               now: Optional[float] = None) -> SpGemmRequest:
        """Queue one multiply; flushes its bucket if that fills it.

        Malformed operands are rejected *here* with a structured
        :class:`~repro.core.formats.InvalidOperand` naming the field —
        they never reach a kernel, and never poison a co-bucketed
        batch."""
        validate_operands(A, B)
        now = self.clock() if now is None else now
        key = bucket_key(A, B)
        req = SpGemmRequest(A=A, B=B, id=self._next_id, t_submit=now,
                            bucket=key)
        self._next_id += 1
        self._by_id[req.id] = req
        q = self._queues.setdefault(key, [])
        if not q:
            self._opened[key] = now
        q.append(req)
        if len(q) >= self.max_batch:
            self._flush(key, now, reason="full")
        return req

    def lookup(self, request_id: int) -> SpGemmRequest:
        """The request for an id — every submitted id resolves here,
        whether it completed, dead-lettered, or is still pending."""
        return self._by_id[request_id]

    @property
    def pending(self) -> int:
        return (sum(len(q) for q in self._queues.values())
                + sum(len(reqs) for _, reqs, *_ in self._inflight.values()))

    # -- flushing --------------------------------------------------------

    def pump(self, now: Optional[float] = None) -> int:
        """Flush every bucket whose oldest request aged past the
        timeout; returns the number of requests completed.

        In multi-process mode this is also the collection point: tasks
        the worker pool finished since the last pump complete here."""
        now = self.clock() if now is None else now
        done = self._collect(block=False)
        for key in [k for k, t in self._opened.items()
                    if now - t >= self.flush_timeout]:
            done += self._flush(key, now, reason="timeout")
        return done

    def drain(self, now: Optional[float] = None,
              timeout: float = 300.0) -> int:
        """Flush everything regardless of age (shutdown / end of bench).

        In multi-process mode, blocks until every dispatched task came
        back (or ``timeout`` expired — the stragglers then run through
        the local ladder, so drain still resolves every request)."""
        now = self.clock() if now is None else now
        done = 0
        for key in list(self._queues):
            done += self._flush(key, now, reason="drain")
        if self._inflight:
            done += self._collect(block=True, timeout=timeout)
            for tid in list(self._inflight):
                # pool never answered: serve the stragglers ourselves
                done += self._finish_remote(
                    tid, {"pool_lost": True, "why": "drain timeout"})
        return done

    def _stick_bucket_cap(self, key: tuple, sp):
        """Pin a bucket's esc product capacity to its running maximum.

        plan_batched sizes cap_products from the flush's actual lane
        works, which can cross a power-of-two boundary between flushes
        of the same pad bucket — a fresh XLA compile mid-steady-state.
        Raising the cap to the bucket's historical max is always safe
        (it is an upper bound) and makes the jit_key stable once the
        bucket has seen its heaviest traffic."""
        if sp.base.engine != "esc":
            return sp
        cap = sp.base.kwargs_dict.get("cap_products")
        sticky = max(cap, self._bucket_caps.get(key, 0))
        self._bucket_caps[key] = sticky
        if sticky == cap:
            return sp
        kwargs = tuple(sorted({**sp.base.kwargs_dict,
                               "cap_products": sticky}.items()))
        return dataclasses.replace(
            sp, base=dataclasses.replace(sp.base, kwargs=kwargs))

    # -- failure handling ------------------------------------------------

    def _dead_letter(self, r: SpGemmRequest, stage: str, kind: str,
                     message: str, attempts: int) -> None:
        r.error = SpgemmError(id=r.id, bucket=r.bucket, stage=stage,
                              kind=kind, message=message, attempts=attempts,
                              t=self.clock())
        r.t_done = self.clock()
        self.dead_letters.append(r)

    def _expire(self, reqs: list, attempts: int) -> list:
        """Dead-letter requests whose age passed the policy deadline;
        returns the survivors."""
        if self.policy.deadline_s is None:
            return reqs
        now = self.clock()
        keep = []
        for r in reqs:
            if now - r.t_submit >= self.policy.deadline_s:
                self._dead_letter(
                    r, "deadline", "DeadlineExceeded",
                    f"age {now - r.t_submit:.3f}s >= deadline "
                    f"{self.policy.deadline_s}s", attempts)
            else:
                keep.append(r)
        return keep

    @staticmethod
    def _check_outputs(out, reqs: list) -> None:
        """Screen every lane of a flush result; silent garbage (injected
        NaNs, out-of-range indices) counts as a failed attempt."""
        for i in range(len(reqs)):
            dp.check_result(out[i])

    def _run_batched(self, reqs: list, key: tuple, planner) -> object:
        """Build the padded batch for ``reqs`` and run one execution
        attempt through ``planner(A, B) -> (plan-ish, execute_fn)``."""
        _, _, cap_a, cap_b = key
        A = batch_csr([r.A for r in reqs], nnz_cap=cap_a,
                      batch_cap=self.max_batch)
        B = batch_csr([r.B for r in reqs], nnz_cap=cap_b,
                      batch_cap=self.max_batch)
        return planner(A, B)

    def _flush(self, key: tuple, now: float, reason: str) -> int:
        """Flush one bucket: dispatched to the worker pool when a
        coordinator is attached, run inline otherwise."""
        if self.coordinator is not None:
            return self._flush_remote(key, now, reason)
        return self._flush_local(key, now, reason)

    # -- multi-process flushing -----------------------------------------

    def _flush_remote(self, key: tuple, now: float, reason: str) -> int:
        """Pack the bucket into a task and hand it to the worker pool.

        Returns 0 — completion is asynchronous; ``pump``/``drain``
        collect.  A pool that is already fully lost degrades to the
        local ladder right here."""
        from repro.runtime import coordinator as coord
        reqs = self._queues.pop(key, [])
        self._opened.pop(key, None)
        if not reqs:
            return 0
        payload = coord.make_flush_payload(
            reqs, bucket=key, engine=self.engine, max_batch=self.max_batch,
            policy=self.policy)
        try:
            tid = self.coordinator.submit(payload)
        except coord.PoolLost:
            self._queues[key] = reqs
            return self._flush_local(key, now, reason)
        self._inflight[tid] = (key, reqs, reason, now, time.perf_counter())
        return 0

    def _collect(self, block: bool, timeout: float = 300.0) -> int:
        """Absorb finished pool tasks into request completions."""
        if self.coordinator is None or not self._inflight:
            return 0
        done = 0
        deadline = time.monotonic() + timeout
        while self._inflight:
            results = self.coordinator.poll(timeout=0.2 if block else 0.0)
            for tid, res in results:
                done += self._finish_remote(tid, res)
            if not block:
                break
            if not results and time.monotonic() >= deadline:
                break
        return done

    def _finish_remote(self, tid: int, res: dict) -> int:
        """Land one pool task's outcome on its requests.

        Success lands per-request results/dead-letters plus the worker's
        flush provenance; ``pool_lost``/``error`` re-queues the bucket
        through the *local* supervised flush — the in-process ladder is
        the fallback of last resort, so every request still resolves."""
        from repro.runtime import coordinator as coord
        inflight = self._inflight.pop(tid, None)
        if inflight is None:
            return 0
        key, reqs, reason, t_flush, t0 = inflight
        if "outcomes" not in res:
            # the pool could not run it (lost / infrastructural error):
            # degrade to the in-process ladder
            self._queues.setdefault(key, []).extend(reqs)
            return self._flush_local(key, t_flush, reason)
        t_done = self.clock()
        done_n = 0
        for r, o in zip(reqs, res["outcomes"]):
            if o["ok"]:
                r.result = coord.unpack_csr(o["result"])
                r.t_done = t_done
                r.engine = o.get("engine")
                r.tier = o.get("tier")
                self.completed.append(r)
                done_n += 1
            else:
                self._dead_letter(r, o.get("stage", "flush"),
                                  o.get("kind", "Error"),
                                  o.get("message", ""),
                                  o.get("attempts", 1))
        f = res.get("flush") or {}
        self.flush_log.append(FlushRecord(
            bucket=key, n_requests=len(reqs),
            engine=f.get("engine", "?"), source=f.get("source", "?"),
            reason=reason, t=t_flush,
            wall_s=time.perf_counter() - t0,
            tier=f.get("tier", "planned"),
            attempts=f.get("attempts", 1),
            n_failed=len(reqs) - done_n,
            errors=tuple(f.get("errors", ()))))
        return done_n

    # -- in-process flushing --------------------------------------------

    def _flush_local(self, key: tuple, now: float, reason: str) -> int:
        """Supervised flush: planned tier with bounded retries, then the
        degradation ladder, then per-request isolation.  Surviving
        requests always complete; failures dead-letter individually."""
        reqs = self._queues.pop(key, [])
        self._opened.pop(key, None)
        if not reqs:
            return 0
        fi.fire("service.flush", bucket=key, reason=reason)
        t0 = time.perf_counter()
        survivors = list(reqs)
        attempts = 0
        errors: list[str] = []
        out = None
        sp = None
        engine, source, tier = "?", "failed", "planned"

        # -- tier 0: the planned sharded flush, with bounded retries ----
        for attempt in range(1, self.policy.max_attempts + 1):
            survivors = self._expire(survivors, attempts)
            if not survivors:
                break
            attempts += 1
            try:
                def planned(A, B):
                    nonlocal sp
                    sp = shard.plan_sharded(A, B, self.engine,
                                            mesh=self.mesh,
                                            cache=self.cache,
                                            rules=self.rules)
                    sp = self._stick_bucket_cap(key, sp)
                    return shard.execute_sharded(sp, A, B)
                out = self._run_batched(survivors, key, planned)
                self._check_outputs(out, survivors)
                engine, source, tier = sp.base.engine, sp.base.source, \
                    "planned"
                break
            except Exception as e:
                errors.append(f"planned#{attempt}: {type(e).__name__}: {e}")
                out = None
                if attempt < self.policy.max_attempts:
                    self.policy.sleep(self.policy.backoff_s(attempt))

        # -- tier 1..n: the degradation ladder --------------------------
        if out is None and survivors:
            if sp is not None:
                # the planned combo kept crashing this bucket: poison it
                # so the next plan does not re-select the same kernel
                self.cache.quarantine(sp.base.cache_key, sp.base.engine,
                                      sp.base.backend,
                                      reason=errors[-1] if errors else "")
            planned_combo = (sp.base.engine, sp.base.backend) \
                if sp is not None else (None, None)
            for eng, bk in self.policy.fallback:
                if (eng, bk) == planned_combo:
                    continue
                spec = dp.available_engines().get(eng)
                if spec is None or not spec.batchable:
                    continue  # non-batchable tiers are the isolation path
                survivors = self._expire(survivors, attempts)
                if not survivors:
                    break
                attempts += 1
                try:
                    def degraded(A, B, eng=eng, bk=bk):
                        bp = dp.plan_batched(A, B, engine=eng,
                                             backend=bk or "auto",
                                             cache=self.cache)
                        return dp.execute_batched(bp, A, B)
                    out = self._run_batched(survivors, key, degraded)
                    self._check_outputs(out, survivors)
                    engine, source = eng, "fallback"
                    tier = f"degraded:{eng}" + (f"/{bk}" if bk else "")
                    break
                except Exception as e:
                    errors.append(f"{eng}/{bk or '-'}: "
                                  f"{type(e).__name__}: {e}")
                    out = None

        done_n = 0
        if out is not None and survivors:
            t_done = self.clock()
            for i, r in enumerate(survivors):
                r.result = out[i]
                r.t_done = t_done
                r.engine = engine
                r.tier = tier
            self.completed.extend(survivors)
            done_n = len(survivors)
        elif survivors:
            # -- final tier: per-request isolation on the reference
            # engine — one poisoned request must not sink its batch ----
            tier, engine, source = "isolated", "scl-array", "isolated"
            for r in survivors:
                survivors_one = self._expire([r], attempts)
                if not survivors_one:
                    continue
                attempts += 1
                try:
                    res = dp.spgemm(r.A, r.B, engine="scl-array",
                                    cache=self.cache)
                    dp.check_result(res)
                    r.result = res
                    r.t_done = self.clock()
                    r.engine = engine
                    r.tier = tier
                    self.completed.append(r)
                    done_n += 1
                except Exception as e:
                    errors.append(f"isolate#{r.id}: {type(e).__name__}: {e}")
                    self._dead_letter(r, "isolate", type(e).__name__,
                                      str(e), attempts)

        wall = time.perf_counter() - t0
        self.flush_log.append(FlushRecord(
            bucket=key, n_requests=len(reqs), engine=engine,
            source=source, reason=reason, t=now, wall_s=wall,
            tier=tier, attempts=max(attempts, 1),
            n_failed=len(reqs) - done_n, errors=tuple(errors)))
        return done_n

    # -- accounting ------------------------------------------------------

    def stats(self, since_request: int = 0, since_flush: int = 0,
              since_dead: int = 0) -> dict:
        """Aggregate serving stats over ``completed[since_request:]`` /
        ``flush_log[since_flush:]`` / ``dead_letters[since_dead:]``
        (snapshot the list lengths at the end of warmup to get
        steady-state numbers)."""
        done = self.completed[since_request:]
        flushes = self.flush_log[since_flush:]
        dead = self.dead_letters[since_dead:]
        lat = np.asarray([r.latency for r in done], np.float64)
        out = {
            "n_requests": len(done),
            "n_flushes": len(flushes),
            "n_buckets": len({f.bucket for f in flushes}),
            "pending": self.pending,
            "n_dead_letters": len(dead),
        }
        resolved = len(done) + len(dead)
        if resolved:
            out["availability"] = len(done) / resolved
        degraded = [r for r in done if r.tier not in (None, "planned")]
        out["n_degraded"] = len(degraded)
        if len(done):
            out["degraded_rate"] = len(degraded) / len(done)
            span = max(r.t_done for r in done) - min(r.t_submit for r in done)
            out["req_per_s"] = len(done) / max(span, 1e-9)
            out["p50_latency_s"] = float(np.percentile(lat, 50))
            out["p95_latency_s"] = float(np.percentile(lat, 95))
            out["mean_latency_s"] = float(lat.mean())
        if degraded:
            dlat = np.asarray([r.latency for r in degraded], np.float64)
            out["p50_latency_degraded_s"] = float(np.percentile(dlat, 50))
            out["p95_latency_degraded_s"] = float(np.percentile(dlat, 95))
        if flushes:
            # request-weighted: the fraction of traffic served off a
            # cached plan (a rare new pad bucket is one small miss-flush,
            # not 1/Nth of the steady state)
            n_req = sum(f.n_requests for f in flushes)
            out["plan_hit_rate"] = (sum(f.n_requests for f in flushes
                                        if f.plan_hit) / n_req)
            out["flush_hit_rate"] = (sum(f.plan_hit for f in flushes)
                                     / len(flushes))
            out["mean_flush_wall_s"] = float(np.mean([f.wall_s
                                                      for f in flushes]))
            out["mean_lanes_per_flush"] = float(np.mean([f.n_requests
                                                         for f in flushes]))
            out["flush_retry_rate"] = (sum(f.attempts > 1 for f in flushes)
                                       / len(flushes))
        return out

    def bucket_outcomes(self) -> dict:
        """Per-bucket autotune outcome: flush count, requests served, the
        engines that ran, and how often selection came from the cache."""
        buckets: dict[tuple, dict] = {}
        for f in self.flush_log:
            b = buckets.setdefault(f.bucket, {
                "flushes": 0, "requests": 0, "plan_hits": 0, "engines": {},
                "degraded": 0, "failed": 0})
            b["flushes"] += 1
            b["requests"] += f.n_requests
            b["plan_hits"] += int(f.plan_hit)
            b["engines"][f.engine] = b["engines"].get(f.engine, 0) + 1
            b["degraded"] += int(f.degraded)
            b["failed"] += f.n_failed
        return buckets
