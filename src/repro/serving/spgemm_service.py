"""Continuous SpGEMM serving: request queue -> bucketed lanes -> sharded plan.

The dispatch layer's caches only pay off under a *stream* of requests —
the ROADMAP's "production traffic" direction.  This service closes that
loop: callers ``submit`` CSR pairs of mixed shapes/densities; requests
are queued per **pad bucket** (operand shapes + power-of-two nnz
bounds), so every flush of a bucket builds ``BatchedCSR`` lanes with
identical array shapes and lands on one already-compiled computation; a
bucket flushes when it reaches ``max_batch`` lanes or its oldest
request ages past ``flush_timeout``.  Execution goes through the
work-balanced sharded plan path (``distributed/spgemm_shard.py``), and
every flush records its plan provenance — after warmup, selections come
from the autotune cache and the plan hit rate approaches 1.

The clock is injectable (and ``submit``/``pump`` take an explicit
``now``) so tests and benchmarks can drive deterministic virtual
traffic; the CLI (``launch/serve_spgemm.py``) and the ``serve``
benchmark section use it against the wall clock.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core import dispatch as dp
from repro.core.formats import CSR, batch_csr
from repro.distributed import spgemm_shard as shard


def _pow2_bucket(n: int) -> int:
    """Power-of-two pad bound >= n (min 16): the nnz capacity every
    request in a bucket is padded to, so one compiled computation serves
    the whole bucket."""
    return 1 << max(4, int(max(int(n), 1) - 1).bit_length())


def bucket_key(A: CSR, B: CSR) -> tuple:
    """(A.shape, B.shape, pad bucket of A.nnz, pad bucket of B.nnz)."""
    nnz_a = int(np.asarray(A.indptr)[-1])
    nnz_b = int(np.asarray(B.indptr)[-1])
    return (A.shape, B.shape, _pow2_bucket(nnz_a), _pow2_bucket(nnz_b))


@dataclasses.dataclass
class SpGemmRequest:
    """One queued multiply; ``result`` lands when its bucket flushes."""

    A: CSR
    B: CSR
    id: int
    t_submit: float
    bucket: tuple
    result: Optional[CSR] = None
    t_done: Optional[float] = None
    engine: Optional[str] = None

    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def latency(self) -> float:
        if self.t_done is None:
            raise ValueError(f"request {self.id} not finished")
        return self.t_done - self.t_submit


@dataclasses.dataclass
class FlushRecord:
    """Per-flush provenance: which bucket ran, on what plan, and why."""

    bucket: tuple
    n_requests: int
    engine: str
    source: str        # "cache" = selection served from the autotune cache
    reason: str        # "full" | "timeout" | "drain"
    t: float
    wall_s: float      # host wall-clock spent executing the flush

    @property
    def plan_hit(self) -> bool:
        return self.source == "cache"


class SpGemmService:
    """Batched continuous serving over the plan/execute dispatch stack.

    max_batch:     lanes per flush (also the BatchedCSR batch_cap, so
                   every flush of a bucket compiles to the same shapes).
    flush_timeout: seconds a bucket may age before ``pump`` flushes it
                   partially filled.
    engine/rules/cache: forwarded to planning (``plan_sharded``).
    mesh:          lane mesh for sharded execution (default: all devices).
    clock:         time source for submit/done stamps (injectable)."""

    def __init__(self, *, max_batch: int = 8, flush_timeout: float = 0.02,
                 engine: str = "auto",
                 mesh=None,
                 cache: Optional[dp.AutotuneCache] = None,
                 rules=dp.DEFAULT_HEURISTICS,
                 clock: Callable[[], float] = time.monotonic):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.flush_timeout = flush_timeout
        self.engine = engine
        self.mesh = mesh
        self.cache = cache if cache is not None else dp.default_cache()
        self.rules = rules
        self.clock = clock
        self._queues: dict[tuple, list[SpGemmRequest]] = {}
        self._opened: dict[tuple, float] = {}
        self._bucket_caps: dict[tuple, int] = {}
        self._next_id = 0
        self.completed: list[SpGemmRequest] = []
        self.flush_log: list[FlushRecord] = []

    # -- intake ----------------------------------------------------------

    def submit(self, A: CSR, B: CSR,
               now: Optional[float] = None) -> SpGemmRequest:
        """Queue one multiply; flushes its bucket if that fills it."""
        if A.n_cols != B.n_rows:
            raise ValueError(f"inner dims differ: {A.shape} @ {B.shape}")
        now = self.clock() if now is None else now
        key = bucket_key(A, B)
        req = SpGemmRequest(A=A, B=B, id=self._next_id, t_submit=now,
                            bucket=key)
        self._next_id += 1
        q = self._queues.setdefault(key, [])
        if not q:
            self._opened[key] = now
        q.append(req)
        if len(q) >= self.max_batch:
            self._flush(key, now, reason="full")
        return req

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- flushing --------------------------------------------------------

    def pump(self, now: Optional[float] = None) -> int:
        """Flush every bucket whose oldest request aged past the
        timeout; returns the number of requests completed."""
        now = self.clock() if now is None else now
        done = 0
        for key in [k for k, t in self._opened.items()
                    if now - t >= self.flush_timeout]:
            done += self._flush(key, now, reason="timeout")
        return done

    def drain(self, now: Optional[float] = None) -> int:
        """Flush everything regardless of age (shutdown / end of bench)."""
        now = self.clock() if now is None else now
        done = 0
        for key in list(self._queues):
            done += self._flush(key, now, reason="drain")
        return done

    def _stick_bucket_cap(self, key: tuple, sp):
        """Pin a bucket's esc product capacity to its running maximum.

        plan_batched sizes cap_products from the flush's actual lane
        works, which can cross a power-of-two boundary between flushes
        of the same pad bucket — a fresh XLA compile mid-steady-state.
        Raising the cap to the bucket's historical max is always safe
        (it is an upper bound) and makes the jit_key stable once the
        bucket has seen its heaviest traffic."""
        if sp.base.engine != "esc":
            return sp
        cap = sp.base.kwargs_dict.get("cap_products")
        sticky = max(cap, self._bucket_caps.get(key, 0))
        self._bucket_caps[key] = sticky
        if sticky == cap:
            return sp
        kwargs = tuple(sorted({**sp.base.kwargs_dict,
                               "cap_products": sticky}.items()))
        return dataclasses.replace(
            sp, base=dataclasses.replace(sp.base, kwargs=kwargs))

    def _flush(self, key: tuple, now: float, reason: str) -> int:
        reqs = self._queues.pop(key, [])
        self._opened.pop(key, None)
        if not reqs:
            return 0
        _, _, cap_a, cap_b = key
        t0 = time.perf_counter()
        A = batch_csr([r.A for r in reqs], nnz_cap=cap_a,
                      batch_cap=self.max_batch)
        B = batch_csr([r.B for r in reqs], nnz_cap=cap_b,
                      batch_cap=self.max_batch)
        sp = shard.plan_sharded(A, B, self.engine, mesh=self.mesh,
                                cache=self.cache, rules=self.rules)
        sp = self._stick_bucket_cap(key, sp)
        out = shard.execute_sharded(sp, A, B)
        wall = time.perf_counter() - t0
        # completion is stamped AFTER execution, so latency includes the
        # flush's own run (and compile) time under a real clock; virtual
        # clocks simply read whatever the test advanced them to
        t_done = self.clock()
        for i, r in enumerate(reqs):
            r.result = out[i]
            r.t_done = t_done
            r.engine = sp.base.engine
        self.completed.extend(reqs)
        self.flush_log.append(FlushRecord(
            bucket=key, n_requests=len(reqs), engine=sp.base.engine,
            source=sp.base.source, reason=reason, t=now, wall_s=wall))
        return len(reqs)

    # -- accounting ------------------------------------------------------

    def stats(self, since_request: int = 0, since_flush: int = 0) -> dict:
        """Aggregate serving stats over ``completed[since_request:]`` /
        ``flush_log[since_flush:]`` (snapshot the list lengths at the end
        of warmup to get steady-state numbers)."""
        done = self.completed[since_request:]
        flushes = self.flush_log[since_flush:]
        lat = np.asarray([r.latency for r in done], np.float64)
        out = {
            "n_requests": len(done),
            "n_flushes": len(flushes),
            "n_buckets": len({f.bucket for f in flushes}),
            "pending": self.pending,
        }
        if len(done):
            span = max(r.t_done for r in done) - min(r.t_submit for r in done)
            out["req_per_s"] = len(done) / max(span, 1e-9)
            out["p50_latency_s"] = float(np.percentile(lat, 50))
            out["p95_latency_s"] = float(np.percentile(lat, 95))
            out["mean_latency_s"] = float(lat.mean())
        if flushes:
            # request-weighted: the fraction of traffic served off a
            # cached plan (a rare new pad bucket is one small miss-flush,
            # not 1/Nth of the steady state)
            n_req = sum(f.n_requests for f in flushes)
            out["plan_hit_rate"] = (sum(f.n_requests for f in flushes
                                        if f.plan_hit) / n_req)
            out["flush_hit_rate"] = (sum(f.plan_hit for f in flushes)
                                     / len(flushes))
            out["mean_flush_wall_s"] = float(np.mean([f.wall_s
                                                      for f in flushes]))
            out["mean_lanes_per_flush"] = float(np.mean([f.n_requests
                                                         for f in flushes]))
        return out

    def bucket_outcomes(self) -> dict:
        """Per-bucket autotune outcome: flush count, requests served, the
        engines that ran, and how often selection came from the cache."""
        buckets: dict[tuple, dict] = {}
        for f in self.flush_log:
            b = buckets.setdefault(f.bucket, {
                "flushes": 0, "requests": 0, "plan_hits": 0, "engines": {}})
            b["flushes"] += 1
            b["requests"] += f.n_requests
            b["plan_hits"] += int(f.plan_hit)
            b["engines"][f.engine] = b["engines"].get(f.engine, 0) + 1
        return buckets
