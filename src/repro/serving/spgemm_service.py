"""Continuous SpGEMM serving: async admission -> bucketed lanes -> plan.

The dispatch layer's caches only pay off under a *stream* of requests —
the ROADMAP's "production traffic" direction.  This service closes that
loop: callers ``submit`` CSR pairs of mixed shapes/densities; requests
are queued per **pad bucket** (operand shapes + power-of-two nnz
bounds), so every flush of a bucket builds ``BatchedCSR`` lanes with
identical array shapes and lands on one already-compiled computation; a
bucket flushes when it reaches ``max_batch`` lanes or its oldest
request ages past ``flush_timeout``.  Execution goes through the
work-balanced sharded plan path (``distributed/spgemm_shard.py``), and
every flush records its plan provenance — after warmup, selections come
from the autotune cache and the plan hit rate approaches 1.

**Async pipeline** (PR 9): admission is cheap and non-blocking — with
``async_flushes > 0`` a full or timed-out bucket is handed to a flush
executor thread (or, with a ``coordinator``, to a worker process) and
``submit`` returns immediately; concurrent buckets flush in parallel
and ``pump``/``drain`` land finished outcomes back onto requests.  The
supervised ladder itself (``_run_ladder``) touches no shared service
state, so flushes of different buckets cannot interleave each other's
bookkeeping; all accounting happens at collection time on the admission
side (``_land``).  ``submit``/``pump``/``drain`` are thread-safe, so
multiple client threads can drive one service.

**Compile-ahead warming**: a :class:`~repro.serving.plan_warmer.
PlanWarmer` predicts upcoming pad buckets (configured traffic classes +
admission-stream frequency + pow2 neighbors) and the service compiles
them ahead of traffic — through ``{"kind": "warm"}`` coordinator tasks
(landing on the same affinity worker that will flush the bucket) or on
the local flush executor — via :func:`repro.core.dispatch.warm_bucket`.
Each flush records whether it landed on a pre-compiled computation
(``FlushRecord.warm_hit``); warmed esc capacities seed the bucket's
sticky cap so real flushes pin to the warmed jit identity.

**Failure model** (the resilience layer of PR 6): operands are
structurally validated at the ``submit`` boundary
(:class:`~repro.core.formats.InvalidOperand` names the bad field); each
flush runs under a supervisor that retries the planned tier with
exponential backoff, walks the degradation ladder
(``core/dispatch.py::DEGRADE_CHAIN``) when the planned kernel keeps
failing — quarantining the poisoned (engine, backend, bucket) combo in
the autotune cache — and finally *isolates* per request on the
dense-accumulator reference engine, so one poisoned request dead-letters
alone instead of failing its whole co-bucketed batch.  Shard-worker loss
mid-flush is recovered one layer down (``_execute_groups``'s supervisor
re-runs the dead worker's lanes on a survivor, bit-identical).  Every
request resolves: ``result`` on success, or a structured
:class:`SpgemmError` on the dead-letter queue.  Per-request deadlines
(``policy.deadline_s``, measured on the service clock from submission)
bound how long a request may be retried before it is dead-lettered.

The clock is injectable (and ``submit``/``pump`` take an explicit
``now``) so tests and benchmarks can drive deterministic virtual
traffic; the CLI (``launch/serve_spgemm.py``) and the ``serve``
benchmark section use it against the wall clock.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent import futures as cf
from typing import Callable, Optional

import numpy as np

from repro.core import dispatch as dp
from repro.core.formats import CSR, batch_csr, validate_operands
from repro.distributed import spgemm_shard as shard
from repro.runtime import faultinject as fi


def _pow2_bucket(n: int) -> int:
    """Power-of-two pad bound >= n (min 16): the nnz capacity every
    request in a bucket is padded to, so one compiled computation serves
    the whole bucket."""
    return 1 << max(4, int(max(int(n), 1) - 1).bit_length())


def bucket_key(A: CSR, B: CSR) -> tuple:
    """(A.shape, B.shape, pad bucket of A.nnz, pad bucket of B.nnz)."""
    nnz_a = int(np.asarray(A.indptr)[-1])
    nnz_b = int(np.asarray(B.indptr)[-1])
    return (A.shape, B.shape, _pow2_bucket(nnz_a), _pow2_bucket(nnz_b))


@dataclasses.dataclass
class SpgemmError:
    """Structured failure result for one request (the dead-letter
    payload): where it failed, why, and after how many attempts."""

    id: int
    bucket: tuple
    stage: str        # "flush" | "isolate" | "deadline"
    kind: str         # exception class name ("DeadlineExceeded", ...)
    message: str
    attempts: int
    t: float

    def __str__(self) -> str:
        return (f"SpgemmError(request {self.id} @ {self.stage}: "
                f"{self.kind}: {self.message})")


@dataclasses.dataclass
class SpGemmRequest:
    """One queued multiply; exactly one of ``result`` / ``error`` lands
    when its bucket flushes (or its deadline expires)."""

    A: CSR
    B: CSR
    id: int
    t_submit: float
    bucket: tuple
    result: Optional[CSR] = None
    error: Optional[SpgemmError] = None
    t_done: Optional[float] = None
    engine: Optional[str] = None
    tier: Optional[str] = None   # "planned" | "degraded:..." | "isolated"

    @property
    def done(self) -> bool:
        return self.result is not None or self.error is not None

    @property
    def failed(self) -> bool:
        return self.error is not None

    @property
    def latency(self) -> float:
        if self.t_done is None:
            raise ValueError(f"request {self.id} not finished")
        return self.t_done - self.t_submit


@dataclasses.dataclass
class FlushRecord:
    """Per-flush provenance: which bucket ran, on what plan, why, and —
    under failure — which tier actually served and at what cost."""

    bucket: tuple
    n_requests: int
    engine: str
    source: str        # plan selection source ("cache", "model", ...)
    reason: str        # "full" | "timeout" | "drain"
    t: float
    wall_s: float      # host wall-clock spent executing the flush
    tier: str = "planned"   # "planned" | "degraded:<engine>" | "isolated"
    attempts: int = 1       # execution attempts across tiers
    n_failed: int = 0       # requests dead-lettered by this flush
    errors: tuple = ()      # per-attempt error trail (str)
    warm_hit: bool = False  # planned tier landed on a pre-compiled jit

    @property
    def plan_hit(self) -> bool:
        # selection that skipped measurement AND the heuristic table:
        # a replayed cache entry or a confident model prediction — both
        # are the "no selection cost paid" steady state
        return self.source in ("cache", "model")

    @property
    def degraded(self) -> bool:
        return self.tier != "planned"


@dataclasses.dataclass
class _FlushOutcome:
    """What one supervised ladder run produced, detached from service
    state: per-request results/dead-letters keyed by position in the
    flushed batch, plus the flush's provenance.  Built by
    ``_run_ladder`` (possibly on an executor thread), applied by
    ``_land`` (always on the admission side, under the service lock)."""

    results: dict      # index -> (CSR result, engine, tier)
    dead: dict         # index -> (stage, kind, message, attempts)
    engine: str
    source: str
    tier: str
    attempts: int
    errors: tuple
    warm_hit: bool = False


class SpGemmService:
    """Batched continuous serving over the plan/execute dispatch stack.

    max_batch:     lanes per flush (also the BatchedCSR batch_cap, so
                   every flush of a bucket compiles to the same shapes).
    flush_timeout: seconds a bucket may age before ``pump`` flushes it
                   partially filled.
    engine/rules/cache: forwarded to planning (``plan_sharded``).
    mesh:          lane mesh for sharded execution (default: all devices).
    clock:         time source for submit/done stamps (injectable).
    policy:        :class:`~repro.core.dispatch.RetryPolicy` governing
                   per-flush retries, backoff, the degradation ladder,
                   and the per-request deadline (``deadline_s``, taken
                   against this service's clock).
    async_flushes: > 0 runs flushes on a thread-pool executor of that
                   size instead of inline: ``submit`` never blocks on a
                   flush, concurrent buckets overlap, and
                   ``pump``/``drain`` land finished outcomes.  0 (the
                   default) keeps the synchronous inline flush.
    warmer:        a :class:`~repro.serving.plan_warmer.PlanWarmer`;
                   when set, ``submit`` feeds it the admission stream,
                   ``pump`` dispatches compile-ahead warm work for the
                   buckets it predicts, and ``prewarm()`` warms
                   configured traffic classes before the first request.
    coordinator:   a :class:`~repro.runtime.coordinator.
                   ProcessCoordinator` — when set, flushes are
                   *dispatched* to its worker processes instead of run
                   inline: ``_flush`` submits a packed task and returns
                   immediately, ``pump``/``drain`` collect finished
                   tasks, and concurrent buckets overlap across worker
                   processes.  Worker death mid-flush is recovered by
                   the coordinator (re-run on a survivor); when the
                   whole pool is lost, the affected requests fall back
                   to this process's own in-process ladder — every
                   submitted id still resolves.
    bucket_caps:   optional shared sticky-cap dict (bucket -> esc
                   cap_products); coordinator workers pass a per-process
                   dict here so caps — and the warmed jit identities
                   they pin — survive across per-task service
                   instances."""

    def __init__(self, *, max_batch: int = 8, flush_timeout: float = 0.02,
                 engine: str = "auto",
                 mesh=None,
                 cache: Optional[dp.AutotuneCache] = None,
                 rules=dp.DEFAULT_HEURISTICS,
                 clock: Callable[[], float] = time.monotonic,
                 policy: Optional[dp.RetryPolicy] = None,
                 async_flushes: int = 0,
                 warmer=None,
                 coordinator=None,
                 bucket_caps: Optional[dict] = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.flush_timeout = flush_timeout
        self.engine = engine
        self.mesh = mesh
        self.cache = cache if cache is not None else dp.default_cache()
        self.rules = rules
        self.clock = clock
        self.policy = policy if policy is not None else dp.RetryPolicy()
        self.coordinator = coordinator
        self.warmer = warmer
        self.async_flushes = int(async_flushes)
        self._executor = (cf.ThreadPoolExecutor(
            max_workers=self.async_flushes,
            thread_name_prefix="spgemm-flush")
            if self.async_flushes > 0 else None)
        # admission/bookkeeping lock: submit/pump/drain are thread-safe
        # (concurrent client threads); ladder threads never take it
        self._mu = threading.RLock()
        self._caps_mu = threading.Lock()
        self._queues: dict[tuple, list[SpGemmRequest]] = {}
        self._opened: dict[tuple, float] = {}
        # sticky esc caps per bucket; injectable so a coordinator worker
        # keeps its caps (and the warmed jit identities they pin) across
        # the per-task service instances it builds
        self._bucket_caps: dict[tuple, int] = \
            bucket_caps if bucket_caps is not None else {}
        self._next_id = 0
        self._by_id: dict[int, SpGemmRequest] = {}
        # coordinator task_id -> (bucket, requests, reason, t_flush, t0)
        self._inflight: dict[int, tuple] = {}
        # local future id -> (bucket, requests, reason, t_flush, t0, fut)
        self._local_inflight: dict[int, tuple] = {}
        self._next_local = 0
        # warm work in flight: coordinator tid -> bucket / local id -> ...
        self._warm_inflight: dict[int, tuple] = {}
        self._local_warm: dict[int, tuple] = {}
        self._next_warm = 0
        self.completed: list[SpGemmRequest] = []
        self.dead_letters: list[SpGemmRequest] = []
        self.flush_log: list[FlushRecord] = []
        self.warm_log: list[dict] = []

    # -- intake ----------------------------------------------------------

    def submit(self, A: CSR, B: CSR,
               now: Optional[float] = None) -> SpGemmRequest:
        """Queue one multiply; flushes its bucket if that fills it.

        Malformed operands are rejected *here* with a structured
        :class:`~repro.core.formats.InvalidOperand` naming the field —
        they never reach a kernel, and never poison a co-bucketed
        batch."""
        validate_operands(A, B)
        with self._mu:
            now = self.clock() if now is None else now
            key = bucket_key(A, B)
            req = SpGemmRequest(A=A, B=B, id=self._next_id, t_submit=now,
                                bucket=key)
            self._next_id += 1
            self._by_id[req.id] = req
            if self.warmer is not None:
                self.warmer.observe(key, A, B)
            q = self._queues.setdefault(key, [])
            if not q:
                self._opened[key] = now
            q.append(req)
            if len(q) >= self.max_batch:
                self._flush(key, now, reason="full")
            return req

    def lookup(self, request_id: int) -> SpGemmRequest:
        """The request for an id — every submitted id resolves here,
        whether it completed, dead-lettered, or is still pending."""
        return self._by_id[request_id]

    @property
    def pending(self) -> int:
        return (sum(len(q) for q in self._queues.values())
                + sum(len(reqs) for _, reqs, *_ in self._inflight.values())
                + sum(len(e[1]) for e in self._local_inflight.values()))

    # -- flushing --------------------------------------------------------

    def pump(self, now: Optional[float] = None) -> int:
        """Flush every bucket whose oldest request aged past the
        timeout; returns the number of requests completed.

        This is also the collection point for every asynchronous
        completion — pool tasks and local executor flushes land here —
        and the background warmer's heartbeat: buckets the warmer
        predicts get their compile-ahead work dispatched."""
        with self._mu:
            now = self.clock() if now is None else now
            done = self._collect(block=False)
            done += self._collect_local()
            self._collect_warm_local()
            for key in [k for k, t in self._opened.items()
                        if now - t >= self.flush_timeout]:
                done += self._flush(key, now, reason="timeout")
            self._pump_warmer()
            return done

    def drain(self, now: Optional[float] = None,
              timeout: float = 300.0) -> int:
        """Flush everything regardless of age (shutdown / end of bench).

        Blocks until every dispatched task and in-flight async flush
        came back (or ``timeout`` expired — remote stragglers then run
        through the local ladder and local stragglers dead-letter, so
        drain still resolves every request)."""
        with self._mu:
            now = self.clock() if now is None else now
            done = 0
            for key in list(self._queues):
                done += self._flush(key, now, reason="drain")
            if self._inflight or self._warm_inflight:
                done += self._collect(block=True, timeout=timeout)
                for tid in list(self._inflight):
                    # pool never answered: serve the stragglers ourselves
                    done += self._finish_remote(
                        tid, {"pool_lost": True, "why": "drain timeout"})
            done += self._wait_local(timeout)
            return done

    def close(self, wait: bool = True) -> None:
        """Shut down the flush executor (no-op without async flushes)."""
        if self._executor is not None:
            self._executor.shutdown(wait=wait, cancel_futures=not wait)

    def _stick_bucket_cap(self, key: tuple, sp):
        """Pin a bucket's esc product capacity to its running maximum.

        plan_batched sizes cap_products from the flush's actual lane
        works, which can cross a power-of-two boundary between flushes
        of the same pad bucket — a fresh XLA compile mid-steady-state.
        Raising the cap to the bucket's historical max is always safe
        (it is an upper bound) and makes the jit_key stable once the
        bucket has seen its heaviest traffic.  Compile-ahead warming
        seeds the same map, so a warmed bucket's first real flush
        already pins to the warmed capacity."""
        if sp.base.engine != "esc":
            return sp
        cap = sp.base.kwargs_dict.get("cap_products")
        with self._caps_mu:
            sticky = max(cap, self._bucket_caps.get(key, 0))
            self._bucket_caps[key] = sticky
        if sticky == cap:
            return sp
        kwargs = tuple(sorted({**sp.base.kwargs_dict,
                               "cap_products": sticky}.items()))
        return dataclasses.replace(
            sp, base=dataclasses.replace(sp.base, kwargs=kwargs))

    # -- failure handling ------------------------------------------------

    def _dead_letter(self, r: SpGemmRequest, stage: str, kind: str,
                     message: str, attempts: int) -> None:
        r.error = SpgemmError(id=r.id, bucket=r.bucket, stage=stage,
                              kind=kind, message=message, attempts=attempts,
                              t=self.clock())
        r.t_done = self.clock()
        self.dead_letters.append(r)

    @staticmethod
    def _check_outputs(out, reqs: list) -> None:
        """Screen every lane of a flush result; silent garbage (injected
        NaNs, out-of-range indices) counts as a failed attempt."""
        for i in range(len(reqs)):
            dp.check_result(out[i])

    def _run_batched(self, reqs: list, key: tuple, planner) -> object:
        """Build the padded batch for ``reqs`` and run one execution
        attempt through ``planner(A, B) -> (plan-ish, execute_fn)``."""
        _, _, cap_a, cap_b = key
        A = batch_csr([r.A for r in reqs], nnz_cap=cap_a,
                      batch_cap=self.max_batch)
        B = batch_csr([r.B for r in reqs], nnz_cap=cap_b,
                      batch_cap=self.max_batch)
        return planner(A, B)

    def _flush(self, key: tuple, now: float, reason: str) -> int:
        """Flush one bucket: dispatched to the worker pool when a
        coordinator is attached, to the flush executor under
        ``async_flushes``, run inline otherwise."""
        if self.coordinator is not None:
            return self._flush_remote(key, now, reason)
        if self._executor is not None:
            return self._flush_async(key, now, reason)
        return self._flush_local(key, now, reason)

    # -- multi-process flushing -----------------------------------------

    def _flush_remote(self, key: tuple, now: float, reason: str) -> int:
        """Pack the bucket into a task and hand it to the worker pool.

        Returns 0 — completion is asynchronous; ``pump``/``drain``
        collect.  A pool that is already fully lost degrades to the
        local ladder right here."""
        from repro.runtime import coordinator as coord
        reqs = self._queues.pop(key, [])
        self._opened.pop(key, None)
        if not reqs:
            return 0
        payload = coord.make_flush_payload(
            reqs, bucket=key, engine=self.engine, max_batch=self.max_batch,
            policy=self.policy)
        with self._caps_mu:
            sticky = self._bucket_caps.get(key)
        if sticky:
            payload["sticky_cap"] = sticky
        try:
            tid = self.coordinator.submit(payload)
        except coord.PoolLost:
            self._queues[key] = reqs
            return self._flush_local(key, now, reason)
        self._inflight[tid] = (key, reqs, reason, now, time.perf_counter())
        return 0

    def _collect(self, block: bool, timeout: float = 300.0) -> int:
        """Absorb finished pool tasks into request completions."""
        if self.coordinator is None or \
                not (self._inflight or self._warm_inflight):
            return 0
        done = 0
        deadline = time.monotonic() + timeout
        while True:
            results = self.coordinator.poll(timeout=0.2 if block else 0.0)
            for tid, res in results:
                if tid in self._warm_inflight:
                    self._finish_warm_remote(tid, res)
                else:
                    done += self._finish_remote(tid, res)
            if not block or not self._inflight:
                break
            if not results and time.monotonic() >= deadline:
                break
        return done

    def _finish_remote(self, tid: int, res: dict) -> int:
        """Land one pool task's outcome on its requests.

        Success lands per-request results/dead-letters plus the worker's
        flush provenance; ``pool_lost``/``error`` re-queues the bucket
        through the *local* supervised flush — the in-process ladder is
        the fallback of last resort, so every request still resolves."""
        from repro.runtime import coordinator as coord
        inflight = self._inflight.pop(tid, None)
        if inflight is None:
            return 0
        key, reqs, reason, t_flush, t0 = inflight
        if "outcomes" not in res:
            # the pool could not run it (lost / infrastructural error):
            # degrade to the in-process ladder
            self._queues.setdefault(key, []).extend(reqs)
            return self._flush_local(key, t_flush, reason)
        t_done = self.clock()
        done_n = 0
        for r, o in zip(reqs, res["outcomes"]):
            if o["ok"]:
                r.result = coord.unpack_csr(o["result"])
                r.t_done = t_done
                r.engine = o.get("engine")
                r.tier = o.get("tier")
                self.completed.append(r)
                done_n += 1
            else:
                self._dead_letter(r, o.get("stage", "flush"),
                                  o.get("kind", "Error"),
                                  o.get("message", ""),
                                  o.get("attempts", 1))
        f = res.get("flush") or {}
        self.flush_log.append(FlushRecord(
            bucket=key, n_requests=len(reqs),
            engine=f.get("engine", "?"), source=f.get("source", "?"),
            reason=reason, t=t_flush,
            wall_s=time.perf_counter() - t0,
            tier=f.get("tier", "planned"),
            attempts=f.get("attempts", 1),
            n_failed=len(reqs) - done_n,
            errors=tuple(f.get("errors", ())),
            warm_hit=bool(f.get("warm_hit", False))))
        return done_n

    # -- async local flushing -------------------------------------------

    def _flush_async(self, key: tuple, now: float, reason: str) -> int:
        """Hand one bucket's ladder to the flush executor and return —
        admission never waits on execution.  ``pump``/``drain`` land
        the outcome."""
        reqs = self._queues.pop(key, [])
        self._opened.pop(key, None)
        if not reqs:
            return 0
        tid = self._next_local
        self._next_local += 1
        fut = self._executor.submit(self._run_ladder, key, list(reqs),
                                    reason)
        self._local_inflight[tid] = (key, reqs, reason, now,
                                     time.perf_counter(), fut)
        return 0

    def _collect_local(self, wait_s: float = 0.0) -> int:
        """Land every finished executor flush; optionally wait up to
        ``wait_s`` for one to finish first."""
        if not self._local_inflight:
            return 0
        if wait_s > 0.0:
            cf.wait([e[5] for e in self._local_inflight.values()],
                    timeout=wait_s, return_when=cf.FIRST_COMPLETED)
        done = 0
        ready = [tid for tid, e in list(self._local_inflight.items())
                 if e[5].done()]
        for tid in ready:
            key, reqs, reason, t_flush, t0, fut = \
                self._local_inflight.pop(tid)
            try:
                outcome = fut.result()
            except Exception as e:  # ladder itself crashed (injected/bug)
                outcome = _FlushOutcome(
                    results={}, dead={}, engine="?", source="failed",
                    tier="failed", attempts=1,
                    errors=(f"{type(e).__name__}: {e}",))
            done += self._land(key, reqs, reason, t_flush, t0, outcome)
        return done

    def _wait_local(self, timeout: float) -> int:
        """Drain-time barrier for executor flushes: wait, land, and
        dead-letter anything still running past the deadline (a hung
        ladder must not leave ids unresolved)."""
        done = 0
        deadline = time.monotonic() + timeout
        while self._local_inflight and time.monotonic() < deadline:
            done += self._collect_local(
                wait_s=min(0.1, max(deadline - time.monotonic(), 0.0)))
        for tid in list(self._local_inflight):
            key, reqs, reason, t_flush, t0, fut = \
                self._local_inflight.pop(tid)
            outcome = _FlushOutcome(
                results={}, dead={}, engine="?", source="failed",
                tier="abandoned", attempts=1,
                errors=("drain timeout: flush still in executor",))
            done += self._land(key, reqs, reason, t_flush, t0, outcome)
        return done

    # -- the supervised ladder ------------------------------------------

    def _run_ladder(self, key: tuple, reqs: list,
                    reason: str) -> _FlushOutcome:
        """One bucket's supervised execution: planned tier with bounded
        retries, then the degradation ladder, then per-request
        isolation.  Reads service config but mutates no shared
        bookkeeping (sticky caps are the one lock-guarded exception), so
        concurrent ladders — different buckets on executor threads —
        cannot interleave each other's state; ``_land`` applies the
        returned outcome under the service lock."""
        fi.fire("service.flush", bucket=key, reason=reason)
        results: dict[int, tuple] = {}
        dead: dict[int, tuple] = {}
        pending = list(enumerate(reqs))
        attempts = 0
        errors: list[str] = []
        out = None
        sp = None
        engine, source, tier = "?", "failed", "planned"
        warm_hit = False

        def expire(pend):
            """Move deadline-passed requests to ``dead``; keep the rest."""
            if self.policy.deadline_s is None:
                return pend
            now = self.clock()
            keep = []
            for i, r in pend:
                if now - r.t_submit >= self.policy.deadline_s:
                    dead[i] = ("deadline", "DeadlineExceeded",
                               f"age {now - r.t_submit:.3f}s >= deadline "
                               f"{self.policy.deadline_s}s", attempts)
                else:
                    keep.append((i, r))
            return keep

        # -- tier 0: the planned sharded flush, with bounded retries ----
        for attempt in range(1, self.policy.max_attempts + 1):
            pending = expire(pending)
            if not pending:
                break
            attempts += 1
            try:
                def planned(A, B):
                    nonlocal sp
                    sp = shard.plan_sharded(A, B, self.engine,
                                            mesh=self.mesh,
                                            cache=self.cache,
                                            rules=self.rules)
                    sp = self._stick_bucket_cap(key, sp)
                    return shard.execute_sharded(sp, A, B)
                out = self._run_batched([r for _, r in pending], key,
                                        planned)
                self._check_outputs(out, pending)
                engine, source, tier = sp.base.engine, sp.base.source, \
                    "planned"
                warm_hit = dp.jit_warmed(sp.base.jit_key)
                break
            except Exception as e:
                errors.append(f"planned#{attempt}: {type(e).__name__}: {e}")
                out = None
                if attempt < self.policy.max_attempts:
                    self.policy.sleep(self.policy.backoff_s(attempt))

        # -- tier 1..n: the degradation ladder --------------------------
        if out is None and pending:
            if sp is not None:
                # the planned combo kept crashing this bucket: poison it
                # so the next plan does not re-select the same kernel
                self.cache.quarantine(sp.base.cache_key, sp.base.engine,
                                      sp.base.backend,
                                      reason=errors[-1] if errors else "")
            planned_combo = (sp.base.engine, sp.base.backend) \
                if sp is not None else (None, None)
            for eng, bk in self.policy.fallback:
                if (eng, bk) == planned_combo:
                    continue
                spec = dp.available_engines().get(eng)
                if spec is None or not spec.batchable:
                    continue  # non-batchable tiers are the isolation path
                pending = expire(pending)
                if not pending:
                    break
                attempts += 1
                try:
                    def degraded(A, B, eng=eng, bk=bk):
                        bp = dp.plan_batched(A, B, engine=eng,
                                             backend=bk or "auto",
                                             cache=self.cache)
                        return dp.execute_batched(bp, A, B)
                    out = self._run_batched([r for _, r in pending], key,
                                            degraded)
                    self._check_outputs(out, pending)
                    engine, source = eng, "fallback"
                    tier = f"degraded:{eng}" + (f"/{bk}" if bk else "")
                    break
                except Exception as e:
                    errors.append(f"{eng}/{bk or '-'}: "
                                  f"{type(e).__name__}: {e}")
                    out = None

        if out is not None and pending:
            for j, (i, _) in enumerate(pending):
                results[i] = (out[j], engine, tier)
        elif pending:
            # -- final tier: per-request isolation on the reference
            # engine — one poisoned request must not sink its batch ----
            tier, engine, source = "isolated", "scl-array", "isolated"
            for i, r in pending:
                if not expire([(i, r)]):
                    continue
                attempts += 1
                try:
                    res = dp.spgemm(r.A, r.B, engine="scl-array",
                                    cache=self.cache)
                    dp.check_result(res)
                    results[i] = (res, engine, tier)
                except Exception as e:
                    errors.append(f"isolate#{r.id}: {type(e).__name__}: {e}")
                    dead[i] = ("isolate", type(e).__name__, str(e), attempts)

        return _FlushOutcome(results=results, dead=dead, engine=engine,
                             source=source, tier=tier,
                             attempts=max(attempts, 1),
                             errors=tuple(errors), warm_hit=warm_hit)

    def _land(self, key: tuple, reqs: list, reason: str, t_flush: float,
              t0: float, outcome: _FlushOutcome) -> int:
        """Apply one ladder outcome to service bookkeeping (admission
        side, under the service lock): stamp results, dead-letter
        failures, append the flush record."""
        t_done = self.clock()
        done_n = 0
        for i, r in enumerate(reqs):
            res = outcome.results.get(i)
            if res is not None:
                r.result, r.engine, r.tier = res
                r.t_done = t_done
                self.completed.append(r)
                done_n += 1
                continue
            d = outcome.dead.get(i)
            if d is None:
                d = ("flush", "Unresolved",
                     "; ".join(outcome.errors) or "no outcome recorded",
                     outcome.attempts)
            self._dead_letter(r, *d)
        self.flush_log.append(FlushRecord(
            bucket=key, n_requests=len(reqs), engine=outcome.engine,
            source=outcome.source, reason=reason, t=t_flush,
            wall_s=time.perf_counter() - t0, tier=outcome.tier,
            attempts=outcome.attempts, n_failed=len(reqs) - done_n,
            errors=outcome.errors, warm_hit=outcome.warm_hit))
        return done_n

    # -- in-process flushing --------------------------------------------

    def _flush_local(self, key: tuple, now: float, reason: str) -> int:
        """Synchronous flush: run the ladder inline and land it."""
        reqs = self._queues.pop(key, [])
        self._opened.pop(key, None)
        if not reqs:
            return 0
        t0 = time.perf_counter()
        outcome = self._run_ladder(key, reqs, reason)
        return self._land(key, reqs, reason, now, t0, outcome)

    # -- compile-ahead warming ------------------------------------------

    def prewarm(self, buckets=None, block: bool = True,
                timeout: float = 300.0) -> int:
        """Warm pad buckets ahead of traffic.

        ``buckets`` defaults to everything the warmer currently
        predicts (configured traffic classes first).  Warm work runs on
        the coordinator pool or the flush executor when available,
        inline otherwise; with ``block`` the call returns only after
        the dispatched warms finished.  Returns the number of buckets
        dispatched."""
        with self._mu:
            if buckets is None:
                buckets = self.warmer.due() if self.warmer is not None \
                    else []
            n = 0
            for b in buckets:
                n += int(self._dispatch_warm(tuple(b)))
            if block:
                self._await_warms(timeout)
            return n

    def _pump_warmer(self) -> None:
        """Dispatch compile-ahead work for freshly predicted buckets —
        only when an async vehicle exists (warming inline from ``pump``
        would block admission, the very thing warming is for)."""
        if self.warmer is None:
            return
        if self.coordinator is None and self._executor is None:
            return
        for bucket in self.warmer.due():
            self._dispatch_warm(bucket)

    def _dispatch_warm(self, bucket: tuple) -> bool:
        """Route one bucket's warm to the pool / executor / inline."""
        sample = self.warmer.sample(bucket) \
            if self.warmer is not None else None
        with self._caps_mu:
            sticky = self._bucket_caps.get(bucket)
        if self.coordinator is not None:
            from repro.runtime import coordinator as coord
            payload = {"kind": "warm", "bucket": bucket,
                       "engine": self.engine, "max_batch": self.max_batch,
                       "sticky_cap": sticky}
            if sample is not None:
                payload["pair"] = (coord.pack_csr(sample[0]),
                                   coord.pack_csr(sample[1]))
            try:
                tid = self.coordinator.submit(payload)
            except coord.PoolLost:
                pass  # fall through to a local warm
            else:
                self._warm_inflight[tid] = (bucket, time.perf_counter())
                if self.warmer is not None:
                    self.warmer.mark_pending(bucket)
                return True
        if self._executor is not None:
            fut = self._executor.submit(self._warm_local, bucket, sample,
                                        sticky)
            tid = self._next_warm
            self._next_warm += 1
            self._local_warm[tid] = (bucket, fut, time.perf_counter())
            if self.warmer is not None:
                self.warmer.mark_pending(bucket)
            return True
        # no async vehicle: warm inline (explicit prewarm path)
        try:
            res = self._warm_local(bucket, sample, sticky)
        except Exception as e:
            self._note_warm_failed(bucket, f"{type(e).__name__}: {e}")
            return False
        self._note_warm_ok(bucket, res)
        return True

    def _warm_local(self, bucket: tuple, sample, sticky) -> dict:
        return dp.warm_bucket(bucket, engine=self.engine,
                              max_batch=self.max_batch, cache=self.cache,
                              mesh=self.mesh, rules=self.rules,
                              sample=sample, sticky_cap=sticky)

    def _note_warm_ok(self, bucket: tuple, res: dict) -> None:
        cap = res.get("cap")
        if cap:
            with self._caps_mu:
                self._bucket_caps[bucket] = max(
                    int(cap), self._bucket_caps.get(bucket, 0))
        self.warm_log.append({"ok": True, **res})
        if self.warmer is not None:
            self.warmer.mark_warmed(bucket)

    def _note_warm_failed(self, bucket: tuple, why: str) -> None:
        self.warm_log.append({"ok": False, "bucket": bucket, "error": why})
        if self.warmer is not None:
            self.warmer.mark_failed(bucket, why)

    def _collect_warm_local(self) -> None:
        for tid in [t for t, e in list(self._local_warm.items())
                    if e[1].done()]:
            bucket, fut, _ = self._local_warm.pop(tid)
            try:
                res = fut.result()
            except Exception as e:
                self._note_warm_failed(bucket, f"{type(e).__name__}: {e}")
            else:
                self._note_warm_ok(bucket, res)

    def _finish_warm_remote(self, tid: int, res: dict) -> None:
        entry = self._warm_inflight.pop(tid, None)
        if entry is None:
            return
        bucket, _ = entry
        w = res.get("warm") if isinstance(res, dict) else None
        if w is None:
            err = res.get("error") or {}
            why = err.get("message") or res.get("why") or "warm failed"
            self._note_warm_failed(bucket, str(why))
        else:
            self._note_warm_ok(bucket, w)

    def _await_warms(self, timeout: float) -> None:
        """Block until in-flight warm work resolved (prewarm barrier)."""
        deadline = time.monotonic() + timeout
        while (self._warm_inflight or self._local_warm) \
                and time.monotonic() < deadline:
            self._collect_warm_local()
            if self._warm_inflight and self.coordinator is not None:
                for tid, res in self.coordinator.poll(timeout=0.1):
                    if tid in self._warm_inflight:
                        self._finish_warm_remote(tid, res)
                    else:
                        self._finish_remote(tid, res)
            elif self._local_warm:
                cf.wait([e[1] for e in self._local_warm.values()],
                        timeout=0.1, return_when=cf.FIRST_COMPLETED)

    # -- accounting ------------------------------------------------------

    def stats(self, since_request: int = 0, since_flush: int = 0,
              since_dead: int = 0) -> dict:
        """Aggregate serving stats over ``completed[since_request:]`` /
        ``flush_log[since_flush:]`` / ``dead_letters[since_dead:]``
        (snapshot the list lengths at the end of warmup to get
        steady-state numbers)."""
        done = self.completed[since_request:]
        flushes = self.flush_log[since_flush:]
        dead = self.dead_letters[since_dead:]
        lat = np.asarray([r.latency for r in done], np.float64)
        out = {
            "n_requests": len(done),
            "n_flushes": len(flushes),
            "n_buckets": len({f.bucket for f in flushes}),
            "pending": self.pending,
            "n_dead_letters": len(dead),
            "n_warmed": sum(1 for w in self.warm_log if w.get("ok")),
        }
        resolved = len(done) + len(dead)
        if resolved:
            out["availability"] = len(done) / resolved
        degraded = [r for r in done if r.tier not in (None, "planned")]
        out["n_degraded"] = len(degraded)
        if len(done):
            out["degraded_rate"] = len(degraded) / len(done)
            span = max(r.t_done for r in done) - min(r.t_submit for r in done)
            out["req_per_s"] = len(done) / max(span, 1e-9)
            out["p50_latency_s"] = float(np.percentile(lat, 50))
            out["p95_latency_s"] = float(np.percentile(lat, 95))
            out["mean_latency_s"] = float(lat.mean())
        if degraded:
            dlat = np.asarray([r.latency for r in degraded], np.float64)
            out["p50_latency_degraded_s"] = float(np.percentile(dlat, 50))
            out["p95_latency_degraded_s"] = float(np.percentile(dlat, 95))
        if flushes:
            # request-weighted: the fraction of traffic served off a
            # cached plan (a rare new pad bucket is one small miss-flush,
            # not 1/Nth of the steady state)
            n_req = sum(f.n_requests for f in flushes)
            out["plan_hit_rate"] = (sum(f.n_requests for f in flushes
                                        if f.plan_hit) / n_req)
            out["flush_hit_rate"] = (sum(f.plan_hit for f in flushes)
                                     / len(flushes))
            # warm hit: the flush landed on a computation compiled ahead
            # of traffic (request-weighted, like plan_hit_rate)
            out["warm_hit_rate"] = (sum(f.n_requests for f in flushes
                                        if f.warm_hit) / n_req)
            out["flush_warm_hit_rate"] = (sum(f.warm_hit for f in flushes)
                                          / len(flushes))
            out["mean_flush_wall_s"] = float(np.mean([f.wall_s
                                                      for f in flushes]))
            out["mean_lanes_per_flush"] = float(np.mean([f.n_requests
                                                         for f in flushes]))
            out["flush_retry_rate"] = (sum(f.attempts > 1 for f in flushes)
                                       / len(flushes))
        return out

    def bucket_outcomes(self) -> dict:
        """Per-bucket autotune outcome: flush count, requests served, the
        engines that ran, and how often selection came from the cache."""
        buckets: dict[tuple, dict] = {}
        for f in self.flush_log:
            b = buckets.setdefault(f.bucket, {
                "flushes": 0, "requests": 0, "plan_hits": 0, "engines": {},
                "degraded": 0, "failed": 0})
            b["flushes"] += 1
            b["requests"] += f.n_requests
            b["plan_hits"] += int(f.plan_hit)
            b["engines"][f.engine] = b["engines"].get(f.engine, 0) + 1
            b["degraded"] += int(f.degraded)
            b["failed"] += f.n_failed
        return buckets
