"""Batched serving engine: continuous prefill+decode over a request queue.

Slots hold independent sequences in a shared KV cache (batch dim). The
engine jit-compiles one prefill and one decode step per (batch, seq-cap)
bucket and runs greedy or top-k sampling. Designed so the same code path
drives the decode_32k / long_500k dry-run shapes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serving import sampler


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    out: Optional[np.ndarray] = None


class Engine:
    def __init__(self, cfg, params, *, max_batch=8, max_seq=256,
                 greedy=True, seed=0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._prefill = jax.jit(
            lambda p, t, c, e: M.prefill(p, cfg, t, c, enc_inp=e),
            static_argnums=())
        self._decode = jax.jit(
            lambda p, t, c, n: M.decode_step(p, cfg, t, c, n))

    def generate(self, requests: List[Request], enc_inp=None) -> List[Request]:
        """Static batching: pad all prompts to one length, decode together."""
        B = len(requests)
        assert B <= self.max_batch
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        cache = M.init_cache(self.cfg, B, self.max_seq,
                             enc_len=self.cfg.num_frontend_tokens)
        logits, cache = self._prefill(self.params, jnp.asarray(toks), cache,
                                      enc_inp)
        outs = [[] for _ in range(B)]
        max_new = max(r.max_new_tokens for r in requests)
        pos = plen
        for t in range(max_new):
            if self.greedy:
                nxt = sampler.greedy(logits)
            else:
                self.key, sk = jax.random.split(self.key)
                nxt = sampler.topk_sample(sk, logits)
            nxt_np = np.asarray(nxt)
            for i in range(B):
                if t < requests[i].max_new_tokens:
                    outs[i].append(int(nxt_np[i]))
            logits, cache = self._decode(self.params, nxt[:, None], cache,
                                         jnp.int32(pos))
            pos += 1
        for i, r in enumerate(requests):
            r.out = np.asarray(outs[i], np.int32)
        return requests
