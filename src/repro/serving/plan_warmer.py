"""Compile-ahead plan warming: predict the serving pad buckets traffic
is about to hit and compile their plans before the first unlucky request.

The serving steady state (``BENCH_serve.json``) is dominated not by
SpGEMM arithmetic but by first-touch XLA compiles: a fresh pad bucket
eats a multi-second compilation inline, and every queued request behind
it inherits that latency.  A :class:`PlanWarmer` closes the gap from two
prediction sources:

  * **configured shapes** — the operator registers representative
    operand pairs (or bare bucket keys) for the traffic classes they
    expect; these warm before the first request arrives (the PyTorch
    inductor ``compile_worker/subproc_pool`` pattern: a pool of warm
    compile workers ahead of demand);
  * **admission-stream frequency** — every ``submit`` reports its
    bucket; observed buckets (and, for nnz-jittered traffic, their
    neighbouring pow2 pad buckets) are warmed in the background so the
    *next* capacity boundary is already compiled when traffic drifts
    across it.

The warmer itself is pure bookkeeping — deterministic, clock-free, and
trivially testable.  Execution is the service's job:
``SpGemmService._dispatch_warm`` routes each due bucket either to a
coordinator worker (a ``{"kind": "warm"}`` task, landing on the same
affinity worker that will serve the bucket's flushes) or onto the local
flush executor, both ultimately calling
:func:`repro.core.dispatch.warm_bucket`.
"""
from __future__ import annotations

import collections
from typing import Iterable, Optional

from repro.core.formats import CSR
from repro.serving.spgemm_service import bucket_key


def neighbor_buckets(bucket: tuple) -> list[tuple]:
    """The adjacent pow2 pad buckets nnz-jittered traffic lands in next.

    A bucket holds nnz in (cap/2, cap]; traffic whose density drifts a
    few percent crosses into (cap, 2cap] or (cap/4, cap/2].  Buckets
    whose capacity cannot be reached by the operand shape (cap >= rows *
    cols) are skipped — no real operand lands there."""
    a_shape, b_shape, cap_a, cap_b = bucket
    out = []
    up = (a_shape, b_shape, cap_a * 2, cap_b * 2)
    if cap_a < a_shape[0] * a_shape[1] or cap_b < b_shape[0] * b_shape[1]:
        out.append(up)
    if cap_a > 16 or cap_b > 16:
        out.append((a_shape, b_shape, max(cap_a // 2, 16),
                    max(cap_b // 2, 16)))
    return out


class PlanWarmer:
    """Predicts which pad buckets to compile ahead, and tracks outcomes.

    configured:   operand pairs ``(A, B)`` (or bare bucket-key tuples)
                  known ahead of traffic; always first in priority.
    neighbors:    also predict the pow2-adjacent buckets of observed
                  traffic (guards the capacity boundaries).
    history:      admission-stream window for frequency ranking.
    min_count:    observations before a bucket is predicted.
    max_warms:    total warm budget (predicted buckets past it wait).
    """

    def __init__(self, *, configured: Iterable = (), neighbors: bool = True,
                 history: int = 256, min_count: int = 1,
                 max_warms: int = 64):
        self.neighbors = neighbors
        self.min_count = max(int(min_count), 1)
        self.max_warms = int(max_warms)
        self._recent: collections.deque = collections.deque(maxlen=history)
        self._counts: collections.Counter = collections.Counter()
        self._samples: dict[tuple, tuple] = {}   # bucket -> (A, B)
        self._sample_nnz: dict[tuple, int] = {}
        self._configured: list[tuple] = []
        self._warmed: set = set()
        self._pending: set = set()
        self._failed: dict[tuple, str] = {}
        for item in configured:
            if isinstance(item, tuple) and len(item) == 2 \
                    and isinstance(item[0], CSR):
                self.configure(*item)
            else:
                self.configure_bucket(tuple(item))

    # -- intake ----------------------------------------------------------

    def configure(self, A: CSR, B: CSR) -> tuple:
        """Register a representative operand pair for an expected traffic
        class; its bucket warms ahead of any admission."""
        b = bucket_key(A, B)
        if b not in self._configured:
            self._configured.append(b)
        self._keep_sample(b, A, B)
        return b

    def configure_bucket(self, bucket: tuple) -> None:
        """Register a bare bucket key (synthetic operands will warm it)."""
        if bucket not in self._configured:
            self._configured.append(bucket)

    def _keep_sample(self, bucket: tuple, A: CSR, B: CSR) -> None:
        # keep the heaviest pair seen: its capacities upper-bound the
        # bucket's traffic best, so the warmed jit covers more flushes
        import numpy as np
        nnz = int(np.asarray(A.indptr)[-1]) + int(np.asarray(B.indptr)[-1])
        if nnz >= self._sample_nnz.get(bucket, -1):
            self._samples[bucket] = (A, B)
            self._sample_nnz[bucket] = nnz

    def observe(self, bucket: tuple, A: Optional[CSR] = None,
                B: Optional[CSR] = None) -> None:
        """Feed one admission (called by ``SpGemmService.submit``)."""
        self._recent.append(bucket)
        self._counts[bucket] += 1
        if A is not None and B is not None:
            self._keep_sample(bucket, A, B)

    # -- prediction ------------------------------------------------------

    def predict(self) -> list[tuple]:
        """Buckets worth compiling, in priority order: configured first,
        then observed by recent frequency, then pow2 neighbors of the
        observed set."""
        out = list(self._configured)
        recent = collections.Counter(self._recent)
        for b, n in recent.most_common():
            if n >= self.min_count and b not in out:
                out.append(b)
        if self.neighbors:
            for b in list(out):
                for nb in neighbor_buckets(b):
                    if nb not in out:
                        out.append(nb)
        return out

    def due(self) -> list[tuple]:
        """The predicted buckets that still need a warm dispatch (not
        warmed, not in flight, not failed, within budget)."""
        budget = self.max_warms - len(self._warmed) - len(self._pending)
        if budget <= 0:
            return []
        out = [b for b in self.predict()
               if b not in self._warmed and b not in self._pending
               and b not in self._failed]
        return out[:budget]

    def sample(self, bucket: tuple) -> Optional[tuple]:
        """The retained (A, B) pair for a bucket, if any was seen."""
        return self._samples.get(bucket)

    # -- outcome tracking ------------------------------------------------

    def mark_pending(self, bucket: tuple) -> None:
        self._pending.add(bucket)

    def mark_warmed(self, bucket: tuple) -> None:
        self._pending.discard(bucket)
        self._failed.pop(bucket, None)
        self._warmed.add(bucket)

    def mark_failed(self, bucket: tuple, why: str = "") -> None:
        self._pending.discard(bucket)
        self._failed[bucket] = why

    def is_warmed(self, bucket: tuple) -> bool:
        return bucket in self._warmed

    def stats(self) -> dict:
        return {"configured": len(self._configured),
                "observed": len(self._counts),
                "warmed": len(self._warmed),
                "pending": len(self._pending),
                "failed": len(self._failed)}
