"""Samplers, including the zipper top-k merge.

With the vocab sharded over the model axis, global top-k = merging 16
per-shard sorted candidate streams — exactly the paper's mszip use case
(merging sorted key-value partitions). ``zipper_topk`` demonstrates the
primitive on real logit streams; the jitted serving path uses the
numerically identical two-level lax.top_k (XLA lowers it to the same
partial-sort + merge schedule under GSPMD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import EMPTY
from repro.kernels import ops as kops


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def topk_sample(key, logits, k=40, temperature=1.0):
    v, idx = jax.lax.top_k(logits, k)
    v = v / jnp.maximum(temperature, 1e-6)
    choice = jax.random.categorical(key, v)
    return jnp.take_along_axis(idx, choice[..., None], -1)[..., 0].astype(jnp.int32)


def zipper_topk(logits_shards, k):
    """Global top-k over per-shard logits via the stream-merge primitive.

    logits_shards: list of (V_loc,) numpy arrays (one per model shard).
    Returns (values, global_ids) of the global top-k, descending.

    Keys must ascend for the zipper, so we merge (-rank) streams keyed by
    negated quantized logits; values carry the global vocab index."""
    R = 1
    while R < k:
        R *= 2
    # one global quantization so keys are comparable across shards; the
    # shard id in the low bits keeps keys unique (the zipper accumulates
    # values of duplicate keys, which would corrupt the carried gids)
    gmax = max(float(lg.max()) for lg in logits_shards)
    n_sh = len(logits_shards)
    streams = []
    for s, lg in enumerate(logits_shards):
        loc = np.argsort(lg)[::-1][:k]              # local top-k, desc
        q = np.round((gmax - lg[loc].astype(np.float64)) * 1e6)
        q = (np.clip(q, 0, 2**26).astype(np.int64) * n_sh + s).astype(np.int32)
        streams.append((q, loc + s * len(lg), lg[loc]))
    # iterative pairwise zipper merge of sorted streams
    parts = []
    for q, gid, val in streams:
        order = np.argsort(q, kind="stable")
        parts.append((q[order], gid[order].astype(np.float32)))
    while len(parts) > 1:
        nxt = []
        for i in range(0, len(parts) - 1, 2):
            (ka, va), (kb, vb) = parts[i], parts[i + 1]
            nxt.append(_merge_two(ka, va, kb, vb, R))
            if i + 3 == len(parts):
                nxt.append(parts[i + 2])
        parts = nxt
    keys, gids = parts[0]
    take = gids[:k].astype(np.int64)
    all_logits = np.concatenate(logits_shards)
    return all_logits[take], take


def _merge_two(ka, va, kb, vb, R):
    """Chunked mszip merge of two sorted (key, gid) streams (host driver
    around the kernel — keys are unique so no accumulation occurs)."""
    out_k, out_v = [], []
    pa = pb = 0
    while pa < len(ka) and pb < len(kb):
        ca, cav = _chunk(ka, va, pa, R)
        cb, cbv = _chunk(kb, vb, pb, R)
        la = np.int32(min(R, len(ka) - pa))
        lb = np.int32(min(R, len(kb) - pb))
        klo, vlo, khi, vhi, na, nb, ol = kops.stream_merge(
            jnp.asarray(ca[None]), jnp.asarray(cav[None]),
            jnp.asarray(la[None]), jnp.asarray(cb[None]),
            jnp.asarray(cbv[None]), jnp.asarray(lb[None]), backend="xla")
        n = int(ol[0])
        merged_k = np.concatenate([np.asarray(klo[0]), np.asarray(khi[0])])[:n]
        merged_v = np.concatenate([np.asarray(vlo[0]), np.asarray(vhi[0])])[:n]
        out_k.append(merged_k)
        out_v.append(merged_v)
        pa += int(na[0])
        pb += int(nb[0])
    out_k.append(ka[pa:])
    out_v.append(va[pa:])
    out_k.append(kb[pb:])
    out_v.append(vb[pb:])
    return np.concatenate(out_k), np.concatenate(out_v)


def _chunk(k, v, p, R):
    ck = np.full(R, EMPTY, np.int32)
    cv = np.zeros(R, np.float32)
    n = min(R, len(k) - p)
    ck[:n] = k[p:p + n]
    cv[:n] = v[p:p + n]
    return ck, cv
