"""Jittable step functions + their input specs and shardings.

These are the units the dry-run lowers and the launchers execute:
  train_step   — fwd + loss + grad + AdamW update (+ grad accumulation)
  prefill_step — prompt -> (first logits, populated KV cache)
  decode_step  — one token for every sequence in the batch
"""
from __future__ import annotations

import functools
import re

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models import model as M
from repro.optim import adamw


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(cfg, opt_cfg: adamw.AdamWConfig):
    accum = max(1, opt_cfg.grad_accum)

    def loss_fn(params, batch):
        loss, met = M.loss_fn(params, cfg, batch)
        return loss, met

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if accum == 1:
            (loss, met), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)
            (grads, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0)), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
            met = {"ce": loss, "aux": jnp.float32(0)}
        new_params, new_opt, om = adamw.apply_updates(
            opt_cfg, params, opt, grads)
        metrics = {"loss": loss, **met, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def train_state_shapes(cfg, opt_cfg, key=None):
    params = jax.eval_shape(
        functools.partial(M.init_params, cfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    opt = jax.eval_shape(functools.partial(adamw.init_state, opt_cfg), params)
    return {"params": params, "opt": opt}


def init_train_state(cfg, opt_cfg, key):
    params = M.init_params(cfg, key)
    return {"params": params, "opt": adamw.init_state(opt_cfg, params)}


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def make_prefill_step(cfg):
    def prefill_step(params, tokens, cache, enc_inp):
        return M.prefill(params, cfg, tokens, cache, enc_inp=enc_inp)
    return prefill_step


def make_decode_step(cfg):
    def decode_step(params, token, cache, cache_len):
        return M.decode_step(params, cfg, token, cache, cache_len)
    return decode_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStructs — nothing is allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg, shape):
    """Batch ShapeDtypeStructs for a ShapeConfig."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sd((B, S), jnp.int32),
                 "labels": sd((B, S), jnp.int32)}
        if cfg.num_frontend_tokens:
            batch["enc_inp"] = sd((B, cfg.num_frontend_tokens, cfg.d_model),
                                  jnp.float32)
        return batch
    if shape.kind == "prefill":
        spec = {"tokens": sd((B, S), jnp.int32),
                "cache": M.cache_shapes(cfg, B, S,
                                        enc_len=cfg.num_frontend_tokens),
                "enc_inp": (sd((B, cfg.num_frontend_tokens, cfg.d_model),
                               jnp.float32)
                            if cfg.num_frontend_tokens else None)}
        return spec
    if shape.kind == "decode":
        return {"token": sd((B, 1), jnp.int32),
                "cache": M.cache_shapes(cfg, B, S,
                                        enc_len=cfg.num_frontend_tokens),
                "cache_len": sd((), jnp.int32)}
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def _nsh(*spec):
    return NamedSharding(shd.get_mesh(), P(*spec))


def batch_shardings(batch):
    ba = shd.batch_axes() or None

    def one(leaf):
        if leaf is None:
            return None
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and ba is not None and \
                leaf.shape[0] % shd.data_axis_size() == 0:
            spec[0] = ba
        return _nsh(*spec)

    return jax.tree_util.tree_map(one, batch,
                                  is_leaf=lambda x: x is None)


_CACHE_RULES = [
    (r"/(k|v|c|kr|enc_k|enc_v)$", 1),   # sequence dim -> model
    (r"/slot_pos$", 1),
    (r"/h$", 1),                         # state width/head dim -> model
    (r"/conv$", 2),                      # channel dim -> model
]


def cache_shardings(cache_tree):
    """Seq-dim model sharding for KV caches; state sharding for SSM."""
    ba = shd.batch_axes() or None
    msize = shd.model_axis_size()
    dsize = shd.data_axis_size()

    def one(path, leaf):
        ps = "/" + "/".join(str(getattr(k, "key", getattr(k, "idx", "")))
                            for k in path)
        stacked = bool(re.match(r"^/g\d+/", ps))
        off = 1 if stacked else 0
        spec = [None] * len(leaf.shape)
        if stacked:
            spec[0] = None
        # batch dim
        bdim = off
        if ba is not None and leaf.shape[bdim] % dsize == 0:
            spec[bdim] = ba
        for pat, dim in _CACHE_RULES:
            if re.search(pat, ps):
                d = dim + off
                if d < len(leaf.shape) and leaf.shape[d] % msize == 0:
                    spec[d] = "model"
                break
        return _nsh(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def state_shardings(cfg, state_shapes):
    p_sh = shd.param_shardings(state_shapes["params"], cfg.fsdp)
    return {
        "params": p_sh,
        "opt": {
            "step": _nsh(),
            "m": jax.tree_util.tree_map(
                lambda s, ps: ps, state_shapes["opt"]["m"], p_sh),
            "v": jax.tree_util.tree_map(
                lambda s, ps: ps, state_shapes["opt"]["v"], p_sh),
        },
    }
