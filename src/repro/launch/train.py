"""Training launcher: mesh + data + resilient loop + checkpoints.

Runs for real on however many devices this host exposes (examples use the
host mesh); the same builder is lowered against the production mesh by the
dry-run. Usage:

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import base as cb
from repro.data.pipeline import PrefetchLoader, TokenDataset
from repro.distributed import sharding as shd
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw
from repro.runtime.fault import FaultConfig, run_resilient


def train(cfg, opt_cfg, fcfg: FaultConfig, *, num_steps: int,
          global_batch: int, seq_len: int, mesh=None, seed: int = 0,
          preempt_hook=None, log_every: int = 10):
    mesh = mesh or make_host_mesh()
    history = []
    with shd.use_mesh(mesh):
        step_fn = st.make_train_step(cfg, opt_cfg)
        state_shapes = st.train_state_shapes(cfg, opt_cfg)
        state_sh = st.state_shardings(cfg, state_shapes)
        jstep = jax.jit(step_fn, in_shardings=(state_sh, None),
                        out_shardings=(state_sh, None), donate_argnums=(0,))

        def fresh_state():
            init = jax.jit(
                functools.partial(st.init_train_state, cfg, opt_cfg),
                out_shardings=state_sh)
            return init(jax.random.PRNGKey(seed))

        ds = TokenDataset(cfg.vocab_size, seq_len, global_batch, seed=seed,
                          enc_tokens=cfg.num_frontend_tokens,
                          d_model=cfg.d_model)
        loader = PrefetchLoader(ds).start()

        def batch_fn(step):
            # step-addressable fetch: on restart the prefetcher rewinds to
            # the restored step so resumed == uninterrupted training
            nonlocal loader
            b = next(loader)
            if b.get("_step") != step:
                loader.stop()
                loader = PrefetchLoader(ds).start(step)
                b = next(loader)
            return b

        def save_fn(step, state):
            return ckpt.save(fcfg.ckpt_dir, step, state, keep=fcfg.keep,
                             blocking=not fcfg.async_save)

        def restore_fn():
            s = ckpt.latest_step(fcfg.ckpt_dir)
            if s is None:
                return None
            state = ckpt.restore(fcfg.ckpt_dir, state_shapes, step=s,
                                 shardings=state_sh)
            return s, state

        def wrapped(state, batch):
            batch = {k: jnp.asarray(v) for k, v in batch.items()
                     if not k.startswith("_")}
            t0 = time.time()
            state, metrics = jstep(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            metrics["step_s"] = time.time() - t0
            return state, metrics

        def on_step(step, metrics):
            history.append(metrics)
            if step % log_every == 0:
                print(f"step {step:5d}  loss {metrics['loss']:.4f}  "
                      f"gnorm {metrics['grad_norm']:.2f}  "
                      f"{metrics['step_s']*1e3:.0f} ms")

        state = fresh_state()
        try:
            state, hist = run_resilient(
                wrapped, state, batch_fn, fcfg, num_steps=num_steps,
                save_fn=save_fn, restore_fn=restore_fn,
                preempt_hook=preempt_hook, on_step=on_step)
        finally:
            loader.stop()
        return state, hist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    cfg = (cb.get_smoke_config(args.arch) if args.smoke
           else cb.get_config(args.arch))
    opt_cfg = adamw.AdamWConfig(lr=args.lr, grad_accum=args.grad_accum,
                                warmup_steps=max(5, args.steps // 10),
                                decay_steps=args.steps,
                                state_dtype=cfg.opt_state_dtype)
    fcfg = FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    _, hist = train(cfg, opt_cfg, fcfg, num_steps=args.steps,
                    global_batch=args.batch, seq_len=args.seq)
    losses = [h["loss"] for h in hist["steps"]]
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({hist['saves']} saves, {hist['restarts']} restarts)")


if __name__ == "__main__":
    main()
