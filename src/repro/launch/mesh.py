"""Production meshes.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — the dry-run must set XLA_FLAGS
before the first jax initialization."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_lane_mesh(n: int | None = None):
    """1-D ("lanes",) mesh over the visible devices — the axis the
    lane-sharded batched SpGEMM path (distributed/spgemm_shard.py) runs
    its shard_map over. ``n`` caps the device count (default: all)."""
    devs = jax.devices()
    n = n or len(devs)
    return jax.make_mesh((n,), ("lanes",), devices=devs[:n])


def make_host_mesh(model_axis: int | None = None):
    """Largest (data, model) mesh on the visible devices (tests, examples)."""
    n = len(jax.devices())
    model = model_axis or (4 if n % 4 == 0 and n >= 4 else 1)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
