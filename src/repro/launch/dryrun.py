import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train / prefill / decode)
against ShapeDtypeStruct inputs on the production mesh (single-pod 16x16 =
256 chips, multi-pod 2x16x16 = 512 chips), compiles it, and records:

  * memory_analysis()  — bytes per device (proves the cell fits),
  * cost_analysis()    — HLO FLOPs / bytes for the roofline terms,
  * collective bytes   — parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute),
  * derived roofline terms for TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI).

Results append to dryrun_results.json (idempotent per cell key) so the full
sweep is resumable.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cb
from repro.distributed import sharding as shd
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import adamw

# TPU v5e roofline constants
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per chip per direction)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in optimized HLO."""
    sizes = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
             "all-to-all": 0, "collective-permute": 0}
    count = dict.fromkeys(sizes, 0)
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8,
                "s16": 2, "u16": 2}
    # lines like: %x = bf16[16,128]{...} all-gather(...)
    pat = re.compile(
        r"=\s*\(?\s*((?:\w+\[[\d,]*\][^ ]*(?:,\s*)?)+)\s*\)?\s*"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"[-a-z]*\(")
    shape_pat = re.compile(r"(\w+)\[([\d,]*)\]")
    for m in pat.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        total = 0
        for sm in shape_pat.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * dt_bytes.get(dt, 4)
        sizes[op] += total
        count[op] += 1
    return {"bytes": sizes, "counts": count,
            "total_bytes": sum(sizes.values())}


def roofline(cost, coll_bytes_per_dev, n_chips, model_flops,
             min_bytes_per_chip=0.0):
    """Three roofline terms + two useful-work fractions.

    roofline_fraction      — FLOPs-based: MODEL_FLOPS time / dominant term
                             (the train/prefill metric).
    memory_fraction        — bytes-based: unavoidable bytes (params read
                             once + cache touched once) / HLO bytes (the
                             decode metric — decode is inherently
                             bandwidth-bound, so efficiency = how close HLO
                             traffic is to the minimum)."""
    flops = cost.get("flops", 0.0)
    bytes_acc = cost.get("bytes accessed", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_bytes_per_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = (model_flops / n_chips) / PEAK_FLOPS if model_flops else 0.0
    return {
        **terms,
        "dominant": dom,
        "step_time_lb_s": bound,
        "model_flops_per_chip": model_flops / n_chips if model_flops else 0,
        "hlo_flops_per_chip": flops,
        "useful_flop_ratio": (model_flops / n_chips / flops) if flops and model_flops else 0.0,
        "roofline_fraction": useful / bound if bound > 0 else 0.0,
        "min_bytes_per_chip": min_bytes_per_chip,
        "memory_fraction": (min_bytes_per_chip / bytes_acc
                            if bytes_acc else 0.0),
    }


def model_flops_for(cfg, shape):
    """MODEL_FLOPS per executed step (6·N·D train; 2·N_active·B decode)."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def _with_reps(cfg, reps: int, enc_layers=None):
    """Variant of cfg with the main group repeated ``reps`` times and scans
    fully unrolled — used for the cost extrapolation (XLA cost_analysis
    counts while-loop bodies exactly once, so roofline terms are measured
    on unrolled 1-/2-rep models and scaled: cost(R) = c1 + (R-1)(c2-c1))."""
    import dataclasses
    n = len(cfg.group_pattern)
    nl = len(cfg.tail_pattern) + cfg.first_k_dense + n * reps
    kw = dict(num_layers=nl, scan_unroll=True)
    if cfg.encoder_layers:
        kw["encoder_layers"] = enc_layers if enc_layers is not None else 1
    return dataclasses.replace(cfg, **kw)


def _cost_metrics(compiled):
    cost = compiled.cost_analysis()
    coll = parse_collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": float(coll["total_bytes"]),
            "coll_by_op": coll["bytes"],
            "coll_counts": coll["counts"]}


def _extrapolate(m1, m2, reps, menc=None, enc_layers=0):
    """cost(R) = c1 + (R-1)·(c2-c1) [+ (E-1)·(c_enc2-c1)]."""
    out = {}
    for k in ("flops", "bytes", "coll"):
        body = m2[k] - m1[k]
        total = m1[k] + (reps - 1) * body
        if menc is not None and enc_layers > 1:
            total += (enc_layers - 1) * (menc[k] - m1[k])
        out[k] = max(total, m1[k])
    out["coll_by_op"] = {
        op: max(m1["coll_by_op"][op] + (reps - 1) *
                (m2["coll_by_op"][op] - m1["coll_by_op"][op]) +
                ((enc_layers - 1) * (menc["coll_by_op"][op] - m1["coll_by_op"][op])
                 if menc is not None and enc_layers > 1 else 0), 0)
        for op in m1["coll_by_op"]}
    return out


GRAD_ACCUM = 1  # set by --grad-accum (perf experiments)


def _build_compiled(cfg, shape):
    """Lower + compile one step function under the active mesh."""
    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype,
                                    grad_accum=GRAD_ACCUM)
        step = st.make_train_step(cfg, opt_cfg)
        state_shapes = st.train_state_shapes(cfg, opt_cfg)
        state_sh = st.state_shardings(cfg, state_shapes)
        batch = st.input_specs(cfg, shape)
        batch_sh = st.batch_shardings(batch)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
        return jitted.lower(state_shapes, batch).compile()
    spec = st.input_specs(cfg, shape)
    params = st.train_state_shapes(cfg, adamw.AdamWConfig())["params"]
    p_sh = shd.param_shardings(params, cfg.fsdp)
    c_sh = st.cache_shardings(spec["cache"])
    if shape.kind == "prefill":
        step = st.make_prefill_step(cfg)
        t_sh = st.batch_shardings(
            {"tokens": spec["tokens"], "enc_inp": spec["enc_inp"]})
        jitted = jax.jit(step, in_shardings=(
            p_sh, t_sh["tokens"], c_sh, t_sh["enc_inp"]),
            donate_argnums=(2,))
        return jitted.lower(params, spec["tokens"], spec["cache"],
                            spec["enc_inp"]).compile()
    step = st.make_decode_step(cfg)
    t_sh = st.batch_shardings({"token": spec["token"]})
    jitted = jax.jit(step, in_shardings=(
        p_sh, t_sh["token"], c_sh, None), donate_argnums=(2,))
    return jitted.lower(params, spec["token"], spec["cache"],
                        spec["cache_len"]).compile()


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg_override=None, verbose: bool = True):
    """Lower + compile one cell. Returns the result record."""
    cfg = cfg_override or cb.get_config(arch)
    shape = cb.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    with shd.use_mesh(mesh):
        compiled = _build_compiled(cfg, shape)
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        # --- cost: extrapolate from unrolled 1-/2-rep variants (see
        # _with_reps docstring; scan bodies are cost-counted once) ---
        main_reps = cfg.groups[0][1]
        m1 = _cost_metrics(_build_compiled(_with_reps(cfg, 1), shape))
        m2 = _cost_metrics(_build_compiled(_with_reps(cfg, 2), shape))
        menc = None
        if cfg.encoder_layers > 1:
            menc = _cost_metrics(
                _build_compiled(_with_reps(cfg, 1, enc_layers=2), shape))
        ext = _extrapolate(m1, m2, main_reps, menc, cfg.encoder_layers)
        cost = {"flops": ext["flops"], "bytes accessed": ext["bytes"]}
        coll = {"total_bytes": ext["coll"], "bytes": ext["coll_by_op"],
                "counts_1rep": m1["coll_counts"]}
        mf = model_flops_for(cfg, shape)
        # unavoidable per-chip traffic: active params once (+ KV cache once
        # for serve steps; + m/v/params updates for train)
        pbytes = cfg.param_count() * jnp.dtype(cfg.param_dtype).itemsize
        if shape.kind == "train":
            opt_b = 2 * cfg.param_count() * jnp.dtype(cfg.opt_state_dtype).itemsize
            min_bytes = (3 * pbytes + 3 * opt_b) / n_chips  # fwd+bwd+update
        else:
            cache_b = sum(
                np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                for l in jax.tree_util.tree_leaves(
                    M.cache_shapes(cfg, shape.global_batch, shape.seq_len,
                                   enc_len=cfg.num_frontend_tokens)))
            act_pb = cfg.active_param_count() * jnp.dtype(cfg.param_dtype).itemsize
            if shape.kind == "prefill":
                min_bytes = (act_pb + cache_b) / n_chips
            else:
                min_bytes = (act_pb * (1 if not cfg.moe else
                                       min(1.0, shape.global_batch * cfg.top_k
                                           / max(1, cfg.num_experts)))
                             + cache_b) / n_chips
        rl = roofline(cost, coll["total_bytes"], n_chips, mf, min_bytes)
        rec = {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_chips": n_chips,
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes_per_device": (
                    getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)),
            },
            "cost": {"flops_per_device": cost.get("flops", 0.0),
                     "bytes_per_device": cost.get("bytes accessed", 0.0)},
            "collectives": coll,
            "roofline": rl,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
        }
        if verbose:
            gb = 1 << 30
            print(f"[{arch} x {shape_name} @ {rec['mesh']}] "
                  f"compile {t_compile:.0f}s  "
                  f"peak {rec['memory']['peak_bytes_per_device']/gb:.2f} GiB/dev  "
                  f"args {rec['memory']['argument_bytes_per_device']/gb:.2f} GiB  "
                  f"terms c/m/x = {rl['compute_s']:.4f}/{rl['memory_s']:.4f}/"
                  f"{rl['collective_s']:.4f}s -> {rl['dominant']} "
                  f"(roofline frac {rl['roofline_fraction']:.3f})")
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--override", default="",
                    help="cfg overrides for perf experiments, e.g. "
                         "attn_block_skip=True,ce_chunk=2048")
    ap.add_argument("--tag", default="",
                    help="suffix for the result key (perf experiments)")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()
    global GRAD_ACCUM
    GRAD_ACCUM = args.grad_accum

    cells = (cb.cells() if args.all
             else [(cb.norm_id(args.arch), args.shape)])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    overrides = {}
    if args.override:
        import ast
        import dataclasses as _dc
        for kv in args.override.split(","):
            k, v = kv.split("=", 1)
            try:
                overrides[k] = ast.literal_eval(v)
            except (ValueError, SyntaxError):
                overrides[k] = v

    try:
        with open(args.out) as f:
            results = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        results = {}

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
            if args.tag:
                key += f"|{args.tag}"
            if args.skip_done and key in results:
                continue
            try:
                cfg_ov = None
                if overrides:
                    import dataclasses as _dc
                    cfg_ov = _dc.replace(cb.get_config(arch), **overrides)
                rec = lower_cell(arch, shape, multi_pod=mp,
                                 cfg_override=cfg_ov)
                if args.tag:
                    rec["tag"] = args.tag
                    rec["overrides"] = overrides
                results[key] = rec
            except Exception as e:
                traceback.print_exc()
                failures.append((key, str(e)[:200]))
                results[key] = {"arch": arch, "shape": shape,
                                "mesh": "2x16x16" if mp else "16x16",
                                "error": str(e)[:500]}
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"\n{len(cells) * len(meshes) - len(failures)} cells OK, "
          f"{len(failures)} failed")
    for k, e in failures:
        print("FAIL", k, e)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
