"""Continuous SpGEMM serving CLI: synthetic mixed traffic -> SpGemmService.

Generates a stream of mixed-shape/mixed-density sparse multiply requests
(the serving request mix the dispatch heuristics distinguish), feeds
them through the bucketed service with work-balanced lane sharding, and
reports steady-state throughput, latency percentiles, and the per-bucket
autotune outcomes.

  PYTHONPATH=src python -m repro.launch.serve_spgemm --requests 200
  PYTHONPATH=src python -m repro.launch.serve_spgemm --requests 400 \\
      --max-batch 8 --timeout 0.05 --engine auto --verify

Chaos mode injects kernel faults (and optionally a worker kill) while
serving, and reports availability, degraded-tier traffic, and the
dead-letter queue:

  PYTHONPATH=src python -m repro.launch.serve_spgemm --requests 200 \\
      --inject-rate 0.1 --kill-worker 0 --deadline 30 --max-attempts 3

Multi-process mode spreads flushes over a supervised pool of spawned
worker processes (``runtime/coordinator.py``); ``--kill-worker-proc``
SIGKILLs worker process 0 mid-flush to demonstrate cross-process
recovery (the task re-runs on a survivor; availability stays 1.0):

  PYTHONPATH=src python -m repro.launch.serve_spgemm --requests 200 \\
      --workers 4
  PYTHONPATH=src python -m repro.launch.serve_spgemm --requests 200 \\
      --workers 2 --kill-worker-proc --inject-rate 0.1

Async + compile-ahead mode keeps admission non-blocking (flushes run on
an executor thread pool or on the worker pool) and pre-compiles the
traffic mix's pad buckets before the first request, so the steady state
never pays a first-touch XLA compile inline:

  PYTHONPATH=src python -m repro.launch.serve_spgemm --requests 200 \\
      --async-flushes 2 --warm
  PYTHONPATH=src python -m repro.launch.serve_spgemm --requests 200 \\
      --workers 4 --warm
"""
from __future__ import annotations

import argparse
import contextlib
import os
import tempfile
import time

import numpy as np

from repro.core import dispatch as dp
from repro.core.formats import random_sparse
from repro.distributed.spgemm_shard import kill_worker_spec
from repro.runtime import faultinject as fi
from repro.serving.spgemm_service import SpGemmService

# (n, density, pattern) mix spanning the heuristic table's regimes
TRAFFIC_MIX = (
    (64, 0.004, "uniform"),
    (64, 0.05, "uniform"),
    (96, 0.02, "powerlaw"),
    (96, 0.008, "banded"),
    (128, 0.01, "uniform"),
    (128, 0.03, "powerlaw"),
)


def make_traffic(n_requests: int, seed: int = 0) -> list:
    """Pre-generate (A, B) request pairs drawn from the traffic mix."""
    rng = np.random.default_rng(seed)
    pairs = []
    for i in range(n_requests):
        n, dens, pattern = TRAFFIC_MIX[int(rng.integers(len(TRAFFIC_MIX)))]
        # jitter density a little so nnz varies inside each pad bucket
        d = dens * float(rng.uniform(0.8, 1.2))
        A = random_sparse(n, n, d, seed=int(rng.integers(1 << 30)),
                          pattern=pattern)
        pairs.append((A, A))
    return pairs


def main() -> None:
    ap = argparse.ArgumentParser(
        description="serve synthetic SpGEMM traffic through the "
                    "plan/execute + lane-sharding stack")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--timeout", type=float, default=0.05,
                    help="bucket flush timeout, seconds")
    ap.add_argument("--engine", default="auto")
    ap.add_argument("--warmup", type=int, default=None,
                    help="requests to exclude from steady-state stats "
                         "(default: a quarter of the stream)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--cache", default=None,
                    help="autotune cache path (default: a fresh temp "
                         "cache, so the warmup->steady-state ramp is "
                         "visible)")
    ap.add_argument("--verify", action="store_true",
                    help="check every result against the scl-array oracle")
    ap.add_argument("--inject-rate", type=float, default=0.0,
                    help="probability a batched kernel launch raises an "
                         "injected fault (chaos mode)")
    ap.add_argument("--kill-worker", type=int, default=None, metavar="DEV",
                    help="kill shard worker DEV once, mid-serve")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline, seconds (expired requests "
                         "dead-letter)")
    ap.add_argument("--max-attempts", type=int, default=3,
                    help="per-flush attempts on the planned tier before "
                         "walking the degradation ladder")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the fault-injection RNG")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="multi-process mode: dispatch flushes to a "
                         "supervised pool of N spawned worker processes "
                         "(0 = in-process serving)")
    ap.add_argument("--kill-worker-proc", action="store_true",
                    help="SIGKILL worker process 0 once, mid-flush "
                         "(requires --workers >= 1)")
    ap.add_argument("--async-flushes", type=int, default=0, metavar="N",
                    help="run flushes on an executor pool of N threads: "
                         "admission never blocks on execution and "
                         "concurrent buckets overlap (0 = synchronous "
                         "inline flushes; ignored under --workers, where "
                         "the process pool is the async vehicle)")
    ap.add_argument("--warm", action="store_true",
                    help="compile-ahead: pre-compile the traffic mix's "
                         "pad buckets (plus their pow2 neighbors) before "
                         "the first request, and keep warming buckets "
                         "predicted from the admission stream")
    args = ap.parse_args()

    cache = dp.AutotuneCache(args.cache or os.path.join(
        tempfile.mkdtemp(prefix="serve_spgemm_"), "autotune.json"))
    policy = dp.RetryPolicy(max_attempts=args.max_attempts,
                            deadline_s=args.deadline)

    coordinator = None
    if args.workers > 0:
        from repro.runtime.coordinator import ProcessCoordinator
        # chaos specs are re-armed *inside* each worker process (they
        # must be picklable, so in-process kill_worker_spec does not
        # apply here — --kill-worker-proc kills the real process)
        pool_specs: dict = {}
        if args.inject_rate > 0.0:
            common = [fi.FaultSpec(site="kernel.batched", kind="raise",
                                   rate=args.inject_rate)]
            pool_specs = {i: list(common) for i in range(args.workers)}
        if args.kill_worker_proc:
            pool_specs.setdefault(0, []).append(
                fi.FaultSpec(site="service.flush", kind="kill_process",
                             max_fires=1))
        coordinator = ProcessCoordinator(
            args.workers, cache_path=cache.path,
            engine=args.engine,
            fault_specs=pool_specs or None, fault_seed=args.chaos_seed)

    warmer = None
    if args.warm:
        from repro.serving.plan_warmer import PlanWarmer
        # one representative pair per traffic class, at nominal density;
        # neighbor warming covers the jittered pow2 boundaries
        reps = [(random_sparse(n, n, d, seed=7 + i, pattern=p),) * 2
                for i, (n, d, p) in enumerate(TRAFFIC_MIX)]
        warmer = PlanWarmer(configured=reps)

    service = SpGemmService(max_batch=args.max_batch,
                            flush_timeout=args.timeout,
                            engine=args.engine, cache=cache,
                            policy=policy, coordinator=coordinator,
                            async_flushes=args.async_flushes
                            if coordinator is None else 0,
                            warmer=warmer)
    if args.warm:
        t_warm = time.perf_counter()
        n_warmed = service.prewarm()
        print(f"# prewarmed {n_warmed} pad buckets in "
              f"{time.perf_counter() - t_warm:.2f}s "
              f"({warmer.stats()['failed']} failed)")

    specs = []
    if args.workers == 0 and args.inject_rate > 0.0:
        specs.append(fi.FaultSpec(site="kernel.batched", kind="raise",
                                  rate=args.inject_rate))
    if args.kill_worker is not None:
        specs.append(kill_worker_spec(args.kill_worker))
    chaos = fi.injected(*specs, seed=args.chaos_seed) if specs \
        else contextlib.nullcontext()
    traffic = make_traffic(args.requests, seed=args.seed)
    warmup = args.warmup if args.warmup is not None else args.requests // 4

    print(f"# serving {args.requests} requests "
          f"({len(TRAFFIC_MIX)} traffic classes, max_batch="
          f"{args.max_batch}, timeout={args.timeout}s)")
    t0 = time.perf_counter()
    snap = (0, 0)
    with chaos:
        for i, (A, B) in enumerate(traffic):
            service.submit(A, B)
            service.pump()
            if i + 1 == warmup:
                # close out the warmup window: flush the partial buckets
                # so every bucket's plan is cached before the
                # steady-state clock
                service.drain()
                snap = (len(service.completed), len(service.flush_log))
        service.drain()
    wall = time.perf_counter() - t0
    service.close()
    if coordinator is not None:
        events = [e["event"] for e in coordinator.events]
        print(f"# pool: {args.workers} workers, "
              f"{coordinator.alive_count} alive at drain | events: "
              + ",".join(f"{e}x{events.count(e)}" for e in sorted(set(events))))
        coordinator.shutdown()

    full = service.stats()
    steady = service.stats(since_request=snap[0], since_flush=snap[1])
    print(f"wall: {wall:.2f}s total, {args.requests / wall:.1f} req/s "
          "(including compiles)")
    for label, s in (("all", full), ("steady", steady)):
        if "req_per_s" not in s:
            continue
        print(f"{label}: {s['n_requests']} reqs in {s['n_flushes']} flushes "
              f"over {s['n_buckets']} buckets | "
              f"req/s={s['req_per_s']:.1f} | "
              f"p50={s['p50_latency_s'] * 1e3:.2f}ms "
              f"p95={s['p95_latency_s'] * 1e3:.2f}ms | "
              f"plan_hit_rate={s.get('plan_hit_rate', 0.0):.2f}"
              + (f" | warm_hit_rate={s.get('warm_hit_rate', 0.0):.2f}"
                 if args.warm else ""))
    if args.inject_rate > 0.0 or args.kill_worker is not None \
            or args.kill_worker_proc:
        tiers: dict = {}
        for r in service.completed:
            tiers[r.tier] = tiers.get(r.tier, 0) + 1
        print(f"chaos: availability={full.get('availability', 1.0):.4f} "
              f"({full['n_dead_letters']} dead-lettered, "
              f"{full['n_degraded']} degraded) | tiers="
              + ",".join(f"{t}x{c}" for t, c in sorted(tiers.items())))
        for r in service.dead_letters:
            print(f"  dead-letter: {r.error}")
    print("# per-bucket outcomes (shape, nnz pad buckets -> engines)")
    for key, b in sorted(service.bucket_outcomes().items()):
        (na, _), (nb, _), cap_a, cap_b = key
        engines = ",".join(f"{e}x{c}" for e, c in sorted(b["engines"].items()))
        print(f"  {na}x{nb} pad=({cap_a},{cap_b}): {b['requests']} reqs / "
              f"{b['flushes']} flushes, hits={b['plan_hits']}, "
              f"engines={engines}")

    if args.verify:
        from repro.core.spgemm import spgemm_scl_array
        for r in service.completed:
            want = np.asarray(spgemm_scl_array(r.A, r.B).to_dense())
            got = np.asarray(r.result.to_dense())
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        print(f"verified {len(service.completed)} results against "
              "the scl-array oracle")


if __name__ == "__main__":
    main()
