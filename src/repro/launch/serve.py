"""Serving launcher: batched requests against any arch.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --requests 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.models import model as M
from repro.serving.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()
    cfg = (cb.get_smoke_config(args.arch) if args.smoke
           else cb.get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    eng = Engine(cfg, params, max_batch=args.requests, max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    enc = None
    if cfg.num_frontend_tokens:
        enc = jax.numpy.asarray(rng.standard_normal(
            (args.requests, cfg.num_frontend_tokens, cfg.d_model)),
            dtype=jax.numpy.float32)
    t0 = time.time()
    reqs = eng.generate(reqs, enc_inp=enc)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    for i, r in enumerate(reqs):
        print(f"req{i}: {r.out[:8].tolist()}...")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
