"""Deterministic, seedable fault injection for the SpGEMM serving stack.

The serving path (plan -> execute -> backend -> shard -> serve) is only
trustworthy under failure if failures can be *manufactured on demand*:
a kernel that raises mid-call, an engine that returns NaN/garbage, a
shard worker that hangs or dies mid-flush, a scribbled-over autotune
cache.  This module is the single registry for those fault sites.

Design constraints:

  * **zero overhead when disabled** — production call sites call
    :func:`fire`/:func:`corrupt`, which are a module-global ``None``
    check when no injector is installed (no spec matching, no RNG);
  * **deterministic** — an installed :class:`FaultInjector` owns one
    seeded ``numpy`` generator; for a fixed seed and call order the
    exact sequence of fired faults is reproducible, so chaos tests can
    assert bit-exact recovery;
  * **structured** — every fired fault is recorded in
    ``injector.events`` (site, kind, call index, context), so tests can
    assert *what* fired, not just that something went wrong.

Fault sites currently threaded through the stack:

  ``dispatch.execute``        single-pair engine call (raise / hang /
                              output corruption) — ``core/dispatch.py``
  ``dispatch.execute_batched`` whole-batch engine call + output
                              corruption — ``core/dispatch.py``
  ``kernel.batched``          per device-group batched driver call (the
                              injected "kernel died mid-pallas_call") —
                              ``core/dispatch.py`` batch drivers
  ``shard.worker``            per shard-worker launch; killing it raises
                              ``WorkerLost`` — ``distributed/spgemm_shard.py``
  ``dispatch.measure``        per autotune measurement —
                              ``core/dispatch.py``
  ``autotune.flush``          cache write-out (cache-corruption site) —
                              ``core/dispatch.py`` AutotuneCache
  ``service.flush``           top of every service flush —
                              ``serving/spgemm_service.py``
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np


class InjectedFault(RuntimeError):
    """Raised at an armed fault site (the default ``kind="raise"``)."""

    def __init__(self, site: str, detail: str = ""):
        self.site = site
        super().__init__(f"injected fault at {site}" +
                         (f": {detail}" if detail else ""))


@dataclasses.dataclass
class FaultSpec:
    """One armed fault: where it fires, how, and how often.

    site:      exact site name (see module docstring).
    kind:      "raise"   -> raise ``exc_factory(site, ctx)``;
               "hang"    -> sleep ``delay_s`` (a stuck worker; pair with
                            a deadline policy);
               "call"    -> invoke ``action(**ctx)`` (escape hatch —
                            e.g. scribble garbage into a cache file);
               "kill_process" -> SIGKILL the *current process* — no
                            cleanup, no exception propagation, exactly
                            what a chaos test means by "the worker
                            process died mid-flush".  Unlike "call",
                            the spec stays picklable, so it can ride a
                            task payload into a spawned worker;
               "nan"     -> corrupt values of a CSR/BatchedCSR result
                            with non-finite payloads (:func:`corrupt`
                            sites only);
               "garbage" -> corrupt column indices out of range
                            (:func:`corrupt` sites only).
    rate:      probability each matching call fires (seeded RNG roll).
    max_fires: stop firing after this many hits (``1`` = kill-once).
    match:     context filter — every (key, value) must equal the
               ``fire``/``corrupt`` call's context for the spec to arm.
    """

    site: str
    kind: str = "raise"
    rate: float = 1.0
    max_fires: Optional[int] = None
    match: dict = dataclasses.field(default_factory=dict)
    exc_factory: Optional[Callable[[str, dict], BaseException]] = None
    action: Optional[Callable] = None
    delay_s: float = 0.0
    fires: int = 0  # mutable: how many times this spec has fired

    def matches(self, site: str, ctx: dict) -> bool:
        if site != self.site:
            return False
        if self.max_fires is not None and self.fires >= self.max_fires:
            return False
        return all(ctx.get(k) == v for k, v in self.match.items())


class FaultInjector:
    """Holds armed :class:`FaultSpec`s plus the seeded RNG and event log."""

    def __init__(self, specs, *, seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        self.specs = list(specs)
        self.rng = np.random.default_rng(seed)
        self.sleep = sleep
        self.events: list[dict] = []
        self.calls = 0

    def _arm(self, site: str, ctx: dict, kinds: tuple):
        """First matching spec whose rate-roll passes, with bookkeeping.

        ``kinds`` scopes the hook type: a value-corruption spec must not
        burn its ``max_fires`` (or its rate roll) on the ``fire()`` call
        that precedes the engine, and vice versa."""
        for spec in self.specs:
            if spec.kind not in kinds or not spec.matches(site, ctx):
                continue
            if spec.rate < 1.0 and float(self.rng.random()) >= spec.rate:
                continue
            spec.fires += 1
            self.events.append({"site": site, "kind": spec.kind,
                                "call": self.calls, **ctx})
            return spec
        return None

    def fire(self, site: str, **ctx) -> None:
        self.calls += 1
        spec = self._arm(site, ctx, ("raise", "hang", "call",
                                     "kill_process"))
        if spec is None:
            return
        if spec.kind == "raise":
            if spec.exc_factory is not None:
                raise spec.exc_factory(site, ctx)
            raise InjectedFault(site, spec.match and repr(spec.match) or "")
        if spec.kind == "hang":
            self.sleep(spec.delay_s)
        elif spec.kind == "kill_process":
            # the real thing, not a simulation: the process is gone
            # before the next Python bytecode runs
            import os
            import signal
            os.kill(os.getpid(), signal.SIGKILL)
        elif spec.action is not None:  # kind == "call"
            spec.action(**ctx)

    def corrupt(self, site: str, value, **ctx):
        self.calls += 1
        spec = self._arm(site, ctx, ("nan", "garbage"))
        if spec is None:
            return value
        return _corrupt_value(value, spec.kind)


def _corrupt_value(value, kind: str):
    """Return a corrupted copy of an engine result.

    Handles any padded-CSR-shaped object (``indices``/``data`` fields on
    a dataclass — CSR and BatchedCSR both qualify) and lists of them;
    anything else is passed through untouched."""
    if isinstance(value, list):
        return [_corrupt_value(v, kind) for v in value]
    if isinstance(value, tuple):  # (csr, stats) engine results
        return (_corrupt_value(value[0], kind),) + tuple(value[1:])
    if not (dataclasses.is_dataclass(value) and hasattr(value, "data")
            and hasattr(value, "indices")):
        return value
    if kind == "nan":
        data = np.asarray(value.data).copy()
        data[...] = np.nan
        import jax.numpy as jnp  # local: keep module import light
        return dataclasses.replace(value, data=jnp.asarray(data))
    idx = np.asarray(value.indices).copy()
    idx[...] = -7  # out-of-range column: must be caught, never served
    import jax.numpy as jnp
    return dataclasses.replace(value, indices=jnp.asarray(idx))


# ---------------------------------------------------------------------------
# module-level install point (the zero-overhead hook)
# ---------------------------------------------------------------------------

_INJECTOR: Optional[FaultInjector] = None


def active() -> Optional[FaultInjector]:
    """The installed injector, or None (the production steady state)."""
    return _INJECTOR


def install(injector: Optional[FaultInjector]) -> None:
    global _INJECTOR
    _INJECTOR = injector


def clear() -> None:
    install(None)


def fire(site: str, **ctx) -> None:
    """Fault hook: no-op unless an injector is installed.

    Call sites pay one global load + ``is None`` test when disabled —
    cheap enough to leave compiled into every layer of the stack."""
    if _INJECTOR is not None:
        _INJECTOR.fire(site, **ctx)


def corrupt(site: str, value: Any, **ctx) -> Any:
    """Value-corruption hook: identity unless an injector is installed."""
    if _INJECTOR is not None:
        return _INJECTOR.corrupt(site, value, **ctx)
    return value


@contextlib.contextmanager
def injected(*specs: FaultSpec, seed: int = 0,
             sleep: Callable[[float], None] = time.sleep):
    """Install a fresh injector for the duration of a with-block."""
    inj = FaultInjector(specs, seed=seed, sleep=sleep)
    prev = _INJECTOR
    install(inj)
    try:
        yield inj
    finally:
        install(prev)
