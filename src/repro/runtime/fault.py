"""Fault tolerance: supervised training loop with checkpoint/restart.

At thousand-node scale the failure model is: some step raises (device
failure, preemption, network partition) and the job must resume from the
last committed checkpoint with bounded lost work. ``run_resilient`` is the
supervisor: it owns checkpoint cadence, failure detection (exceptions +
non-finite loss), bounded retries with re-initialization from disk, and a
preemption hook for injection in tests.

Straggler mitigation for the data path lives in data/pipeline.py
(deadline + backup fetch); compute-side straggler policy at real scale is
handled by the synchronous collectives themselves — what the framework
contributes is fast restart (this module) and elastic re-sharding
(checkpoint/ckpt.py restore with new-mesh shardings).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Optional

log = logging.getLogger("repro.fault")


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 3
    async_save: bool = True


class Preempted(RuntimeError):
    """Raised by the preemption hook (tests / SIGTERM handlers)."""


def _safe_restore(restore_fn: Callable):
    """``restore_fn()`` hardened: a restore that *itself* raises (corrupt
    checkpoint, unreadable dir) means "no usable checkpoint" — the
    supervisor restarts cold instead of crashing out of the loop."""
    try:
        return restore_fn()
    except Exception as e:
        log.warning("restore failed (%s); treating as no checkpoint", e)
        return None


def _safe_join(pending_save) -> None:
    """Join an async save, swallowing its failure (the checkpoint is an
    optimization — a failed save must never take the training run down
    or leak the pending handle)."""
    if pending_save is None:
        return
    try:
        pending_save.join()
    except Exception as e:
        log.warning("pending checkpoint save failed on join (%s)", e)


def run_resilient(train_step: Callable, state: Any, batch_fn, fcfg: FaultConfig,
                  *, num_steps: int, save_fn: Callable, restore_fn: Callable,
                  preempt_hook: Optional[Callable[[int], None]] = None,
                  on_step: Optional[Callable] = None):
    """Generic supervised loop.

    train_step(state, batch) -> (state, metrics)
    batch_fn(step) -> batch — MUST be step-addressable so that a restart
    replays exactly the batches after the restored step (the deterministic
    pipeline makes resumed training bitwise-identical to uninterrupted
    training; see tests/test_system.py::test_resume_bitwise_equivalence).
    save_fn(step, state); restore_fn() -> (step, state) or None.
    Returns (state, history dict).

    Failure accounting: only *step* failures (exceptions out of the
    train step, non-finite loss, preemption) count against
    ``max_restarts``.  A ``save_fn`` that raises is logged under
    ``hist["save_failures"]`` and training continues — a flaky
    checkpoint disk must not burn restart budget; a ``restore_fn`` that
    raises counts as "no checkpoint" and the restart goes back to step
    0.  The pending async save handle is always joined, including on
    every failure path."""
    restarts = 0
    hist = {"steps": [], "restarts": 0, "saves": 0, "save_failures": 0}
    resumed = _safe_restore(restore_fn)
    step = 0
    if resumed is not None:
        step, state = resumed
        log.info("resumed at step %d", step)
    pending_save = None
    try:
        while step < num_steps:
            try:
                if preempt_hook is not None:
                    preempt_hook(step)
                batch = batch_fn(step)
                state, metrics = train_step(state, batch)
                loss = float(metrics.get("loss", 0.0))
                if loss != loss:  # NaN: treat as corrupt step -> restart
                    raise FloatingPointError(f"non-finite loss at step {step}")
                hist["steps"].append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
                if on_step is not None:
                    on_step(step, metrics)
                step += 1
                if step % fcfg.ckpt_every == 0 or step == num_steps:
                    # a failed save is logged, not restarted: the step
                    # already committed and re-running it would double
                    # its work for a checkpoint-disk problem
                    try:
                        _safe_join(pending_save)
                        pending_save = save_fn(step, state)
                        hist["saves"] += 1
                    except Exception as e:
                        pending_save = None
                        hist["save_failures"] += 1
                        log.warning("checkpoint save at step %d failed "
                                    "(%s); continuing", step, e)
            except (Preempted, FloatingPointError, RuntimeError) as e:
                restarts += 1
                hist["restarts"] = restarts
                if restarts > fcfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={fcfg.max_restarts}") from e
                log.warning("step %d failed (%s); restarting (%d/%d)",
                            step, e, restarts, fcfg.max_restarts)
                _safe_join(pending_save)
                pending_save = None
                resumed = _safe_restore(restore_fn)
                if resumed is None:
                    step = 0
                else:
                    step, state = resumed
    finally:
        _safe_join(pending_save)
    return state, hist
