"""Runtime resilience layer: supervised restart, elasticity, fault injection.

``fault.run_resilient`` is the checkpoint/restart supervisor for
training; ``faultinject`` is the deterministic fault-injection registry
the SpGEMM serving stack (dispatch -> shard -> serve) threads its fault
sites through.  The serving-side failure *policies* (retry/backoff,
degradation ladder, quarantine) live where the execute path lives —
``core/dispatch.py`` — and the flush supervisor in
``serving/spgemm_service.py``.
"""
from repro.runtime import faultinject
from repro.runtime.fault import FaultConfig, Preempted, run_resilient

__all__ = ["FaultConfig", "Preempted", "faultinject", "run_resilient"]
