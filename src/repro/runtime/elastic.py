"""Elastic scaling: rebuild the mesh at a new size and reshard state.

The mechanism is deliberately thin because the substrate makes it cheap:
  * checkpoints are mesh-agnostic (host numpy),
  * shardings are derived from (config, mesh) — not stored,
  * the data pipeline is deterministic in (seed, step, shard),
so scaling from N to M pods is: build new mesh -> derive shardings ->
restore latest checkpoint with them -> continue at the saved step.
"""
from __future__ import annotations

import jax

from repro.checkpoint import ckpt
from repro.distributed import sharding as shd


def reshard_restore(ckpt_dir: str, target_tree, mesh, *, fsdp: bool,
                    step=None):
    """Restore a params/opt pytree onto ``mesh`` (any size)."""
    with shd.use_mesh(mesh):
        shardings = shd.param_shardings(
            jax.eval_shape(lambda: target_tree), fsdp)
        return ckpt.restore(ckpt_dir, target_tree, step=step,
                            shardings=shardings)


def remesh(n_devices: int, *, multi_pod: bool = False):
    """Build the largest (data, model) mesh for the available devices,
    holding the model axis fixed and scaling the data axis — the policy a
    resize controller would use when pods join/leave."""
    from repro.launch.mesh import make_production_mesh  # lazy
    try:
        return make_production_mesh(multi_pod=multi_pod)
    except Exception:
        devs = jax.devices()[:n_devices]
        model = min(16, len(devs))
        data = len(devs) // model
        return jax.make_mesh((data, model), ("data", "model"),
                             devices=devs[: data * model])


def remesh_lanes(n_lanes: int, n_workers: int) -> list[range]:
    """Partition ``n_lanes`` device lanes over ``n_workers`` processes.

    The lane-sharding analogue of :func:`remesh`, used by the process
    coordinator (``runtime/coordinator.py``) to (re)assign lane
    ownership when workers join or leave: contiguous slices, sizes
    differing by at most one, earlier workers taking the remainder.
    With more workers than lanes, the surplus workers share lane 0
    (every worker must own at least one lane to be schedulable — a
    lane-less worker could never run a flush).  Deterministic in
    (n_lanes, n_workers), so every process computes the same partition
    without coordination."""
    if n_workers < 1:
        raise ValueError(f"n_workers must be >= 1, got {n_workers}")
    if n_lanes < 1:
        raise ValueError(f"n_lanes must be >= 1, got {n_lanes}")
    if n_workers > n_lanes:
        # surplus workers share lane 0 rather than idling
        return [range(0, 1) if i >= n_lanes else range(i, i + 1)
                for i in range(n_workers)]
    base, rem = divmod(n_lanes, n_workers)
    out, lo = [], 0
    for i in range(n_workers):
        hi = lo + base + (1 if i < rem else 0)
        out.append(range(lo, hi))
        lo = hi
    return out
