"""Elastic scaling: rebuild the mesh at a new size and reshard state.

The mechanism is deliberately thin because the substrate makes it cheap:
  * checkpoints are mesh-agnostic (host numpy),
  * shardings are derived from (config, mesh) — not stored,
  * the data pipeline is deterministic in (seed, step, shard),
so scaling from N to M pods is: build new mesh -> derive shardings ->
restore latest checkpoint with them -> continue at the saved step.
"""
from __future__ import annotations

import jax

from repro.checkpoint import ckpt
from repro.distributed import sharding as shd


def reshard_restore(ckpt_dir: str, target_tree, mesh, *, fsdp: bool,
                    step=None):
    """Restore a params/opt pytree onto ``mesh`` (any size)."""
    with shd.use_mesh(mesh):
        shardings = shd.param_shardings(
            jax.eval_shape(lambda: target_tree), fsdp)
        return ckpt.restore(ckpt_dir, target_tree, step=step,
                            shardings=shardings)


def remesh(n_devices: int, *, multi_pod: bool = False):
    """Build the largest (data, model) mesh for the available devices,
    holding the model axis fixed and scaling the data axis — the policy a
    resize controller would use when pods join/leave."""
    from repro.launch.mesh import make_production_mesh  # lazy
    try:
        return make_production_mesh(multi_pod=multi_pod)
    except Exception:
        devs = jax.devices()[:n_devices]
        model = min(16, len(devs))
        data = len(devs) // model
        return jax.make_mesh((data, model), ("data", "model"),
                             devices=devs[: data * model])
