"""Cross-process worker supervision for multi-process SpGEMM serving.

PR 6's resilience layer stops at the process boundary: ``WorkerLost``
recovery, quarantine, and the degradation ladder all live inside one
Python process.  This module is the scale-out step: a
:class:`ProcessCoordinator` spawns a pool of **worker processes**
(multiprocessing, spawn context), each owning a slice of device lanes
(partitioned by :func:`repro.runtime.elastic.remesh_lanes` and realised
as a per-worker ``make_lane_mesh``), and supervises them the way
``distributed/spgemm_shard._execute_groups`` supervises in-process
shard workers — generalised to real processes that can be SIGKILLed:

  * **task dispatch** — the serving layer submits *flush tasks*
    (a pad bucket's worth of packed CSR pairs); the coordinator routes
    each by **bucket affinity** (rendezvous hashing over the live
    workers), so repeat flushes of a pad bucket land on the worker that
    already compiled it — per-process XLA jit caches make spreading a
    bucket across workers a re-compile, not a speedup, which is exactly
    how ``serve.multiproc.w4`` used to run slower than w2.  The
    affinity worker being busy queues the task (another worker that has
    *seen* the bucket may take it); only a real backlog
    (``affinity_spill``) spills it to a cold idle worker.  The worker
    runs each flush through a local :class:`~repro.serving.
    spgemm_service.SpGemmService` — so every worker process carries the
    full PR 6 ladder (retries, degradation, per-request isolation,
    structured dead letters) — and keeps its sticky esc caps across
    tasks, pinning repeat flushes to one jit identity;
  * **compile-ahead warming** — ``{"kind": "warm"}`` tasks route
    through the same affinity, so a bucket's plan is compiled (
    :func:`repro.core.dispatch.warm_bucket`) in the very worker its
    flushes will land on; warmed selections also propagate cross-
    process through the shared autotune cache file;
  * **death detection** — a killed worker is noticed by pipe EOF (plus
    ``exitcode``); its in-flight tasks are re-queued onto survivors
    (preferring a *different* worker), so a SIGKILL mid-flush costs
    latency, never a dropped request;
  * **hang detection** — a worker whose oldest in-flight task ages past
    ``task_timeout_s`` is declared hung, SIGKILLed, and treated as
    lost; idle workers are liveness-checked with ping/pong heartbeats
    (:meth:`heartbeat`) under ``heartbeat_timeout_s``;
  * **bounded restarts** — each lost worker is respawned at most
    ``max_worker_restarts`` times; past the budget it stays dead and
    the pool shrinks;
  * **elastic re-meshing** — every membership change re-partitions the
    lane space over the live workers (``elastic.remesh_lanes``) and
    tells each survivor to rebuild its lane mesh, so a shrunken pool
    spreads over the full device set and a restarted worker grows it
    back;
  * **shared state by protocol, not by pipe** — workers share the
    autotune + quarantine cache through its on-disk file: quarantine
    pushes immediately (``AutotuneCache.quarantine`` flushes) and plan
    misses pull (``AutotuneCache.refresh``), so a kernel crash observed
    in worker A is routed around by worker B without B ever executing
    the poisoned combo;
  * **total loss is survivable** — when no worker is live and no
    restart budget remains, queued work is handed back marked
    ``pool_lost`` and :meth:`submit` raises :class:`PoolLost`; the
    serving layer's in-process degradation ladder is the fallback.

Fault injection composes: per-worker :class:`~repro.runtime.faultinject.
FaultSpec` lists (picklable — no lambdas) are re-armed inside each
spawned process, so chaos tests arm a ``kill_process`` spec in worker 0
and 10% kernel faults everywhere, then assert availability 1.0.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import multiprocessing as mp
import multiprocessing.connection as mpc
import os
import signal
import sys
import time
from typing import Any, Optional, Sequence, Union

from repro.runtime import faultinject as fi


class PoolLost(RuntimeError):
    """Every worker is dead and the restart budget is exhausted."""


# ---------------------------------------------------------------------------
# task payloads: packed (host numpy) CSR pairs, picklable end to end
# ---------------------------------------------------------------------------


def pack_csr(m) -> tuple:
    """CSR -> (indptr, indices, data, shape) host-numpy tuple.

    Device arrays are pulled to host before pickling so the payload
    crosses the process boundary without touching jax transfer guards."""
    import numpy as np
    return (np.asarray(m.indptr), np.asarray(m.indices),
            np.asarray(m.data), tuple(m.shape))


def unpack_csr(t):
    """Inverse of :func:`pack_csr` (device placement is the unpacker's)."""
    import jax.numpy as jnp
    from repro.core.formats import CSR
    return CSR(jnp.asarray(t[0]), jnp.asarray(t[1]), jnp.asarray(t[2]),
               tuple(t[3]))


def make_flush_payload(reqs, *, bucket: tuple, engine: str, max_batch: int,
                       policy=None) -> dict:
    """Build a flush-task payload from service requests (id order kept)."""
    payload: dict[str, Any] = {
        "bucket": bucket,
        "pairs": [(pack_csr(r.A), pack_csr(r.B)) for r in reqs],
        "engine": engine,
        "max_batch": max_batch,
    }
    if policy is not None:
        payload["policy"] = {
            "max_attempts": policy.max_attempts,
            "backoff_base_s": policy.backoff_base_s,
            "backoff_factor": policy.backoff_factor,
            "fallback": tuple(policy.fallback),
        }
    return payload


# ---------------------------------------------------------------------------
# the worker process
# ---------------------------------------------------------------------------


def _run_flush(payload: dict, *, cache, mesh, caps: dict) -> dict:
    """Execute one flush task through a local SpGemmService.

    The local service is the whole PR 6 stack in miniature: planned
    sharded tier with retries, the degradation ladder, per-request
    isolation — its quarantines push to the shared cache file and its
    plan misses pull from it.  ``caps`` is the worker's *persistent*
    sticky-cap map (shared across tasks and with warm tasks), so repeat
    flushes of a bucket — and flushes after a compile-ahead warm — pin
    to one jit identity instead of recompiling per task.  Returns
    per-request outcomes (packed results or structured errors, id order
    preserved) plus the flush's provenance record."""
    from repro.core import dispatch as dp
    from repro.serving.spgemm_service import SpGemmService

    pairs = payload["pairs"]
    pol = payload.get("policy")
    policy = dp.RetryPolicy(**pol) if pol else dp.RetryPolicy()
    bucket = payload.get("bucket")
    sticky = payload.get("sticky_cap")
    if bucket is not None and sticky:
        caps[bucket] = max(int(sticky), caps.get(bucket, 0))
    svc = SpGemmService(
        max_batch=max(int(payload.get("max_batch", len(pairs))), len(pairs)),
        flush_timeout=0.0, engine=payload.get("engine", "auto"),
        mesh=mesh, cache=cache, policy=policy, bucket_caps=caps)
    reqs = [svc.submit(unpack_csr(a), unpack_csr(b)) for a, b in pairs]
    svc.drain()
    outcomes = []
    for r in reqs:
        if r.error is not None:
            outcomes.append({"ok": False, "stage": r.error.stage,
                             "kind": r.error.kind,
                             "message": r.error.message,
                             "attempts": r.error.attempts})
        else:
            outcomes.append({"ok": True, "result": pack_csr(r.result),
                             "engine": r.engine, "tier": r.tier})
    f = svc.flush_log[-1] if svc.flush_log else None
    flush = None
    if f is not None:
        flush = {"engine": f.engine, "source": f.source, "tier": f.tier,
                 "attempts": f.attempts, "errors": list(f.errors),
                 "wall_s": f.wall_s, "warm_hit": f.warm_hit}
    return {"outcomes": outcomes, "flush": flush}


def _run_warm(payload: dict, *, cache, mesh, caps: dict) -> dict:
    """Execute one compile-ahead warm task: compile a pad bucket's plan
    in this worker before its first flush arrives.

    Fires the ``service.warm`` fault site (chaos tests SIGKILL workers
    mid-warm here) and seeds the worker's persistent sticky-cap map, so
    the bucket's real flushes pin to the warmed jit identity."""
    from repro.core import dispatch as dp

    bucket = payload["bucket"]
    fi.fire("service.warm", bucket=bucket)
    pair = payload.get("pair")
    sample = (unpack_csr(pair[0]), unpack_csr(pair[1])) if pair else None
    res = dp.warm_bucket(bucket, engine=payload.get("engine", "auto"),
                         max_batch=int(payload.get("max_batch", 8)),
                         cache=cache, mesh=mesh, sample=sample,
                         sticky_cap=payload.get("sticky_cap"))
    cap = res.get("cap")
    if cap:
        caps[bucket] = max(int(cap), caps.get(bucket, 0))
    return {"warm": res}


def _worker_main(conn, worker_id: int, init: dict) -> None:
    """Entry point of a spawned worker (module top level: picklable).

    Protocol (parent -> worker): ``("task", id, payload)``,
    ``("ping", seq)``, ``("remesh", n_lanes)``, ``("stop",)``.
    Worker -> parent: ``("ready", pid, n_devices)``,
    ``("result", id, out)``, ``("error", id, kind, message)``,
    ``("pong", seq)``.  One task at a time — parallelism is across
    workers, serialization within one is what makes re-queue exact."""
    for p in reversed(init.get("sys_path", [])):
        if p not in sys.path:
            sys.path.insert(0, p)
    specs = init.get("fault_specs") or []
    if specs:
        fi.install(fi.FaultInjector(
            specs, seed=int(init.get("fault_seed", 0)) + worker_id))
    # heavy imports after fault arming, before "ready": a worker that
    # cannot import does not count as started
    import jax
    from repro.core import dispatch as dp
    from repro.launch.mesh import make_lane_mesh

    n_dev = len(jax.devices())
    n_lanes = max(1, min(int(init.get("n_lanes", 1)), n_dev))
    mesh = make_lane_mesh(n_lanes)
    cache = (dp.AutotuneCache(init["cache_path"])
             if init.get("cache_path") else dp.default_cache())
    # sticky esc caps, persistent across this worker's tasks: the flush
    # of a warmed/previously-seen bucket reuses its jit identity
    caps: dict = {}
    conn.send(("ready", os.getpid(), n_dev))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break  # parent is gone; nothing left to serve
        tag = msg[0]
        if tag == "stop":
            break
        if tag == "ping":
            conn.send(("pong", msg[1]))
            continue
        if tag == "remesh":
            n_lanes = max(1, min(int(msg[1]), n_dev))
            mesh = make_lane_mesh(n_lanes)
            continue
        # ("task", task_id, payload)
        _, task_id, payload = msg
        try:
            if payload.get("kind") == "warm":
                out = _run_warm(payload, cache=cache, mesh=mesh, caps=caps)
            else:
                out = _run_flush(payload, cache=cache, mesh=mesh, caps=caps)
            conn.send(("result", task_id, out))
        except Exception as e:
            try:
                conn.send(("error", task_id, type(e).__name__, str(e)))
            except (OSError, ValueError):
                break
    try:
        conn.close()
    except OSError:
        pass


# ---------------------------------------------------------------------------
# the coordinator (parent side)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Task:
    id: int
    payload: dict
    tries: int = 0

    @property
    def bucket_id(self) -> Optional[str]:
        b = self.payload.get("bucket")
        return None if b is None else repr(b)


def _hrw(bucket_id: str, worker_id: int) -> int:
    """Rendezvous (highest-random-weight) score of a worker for a bucket.

    blake2s, not ``hash()``: stable across processes and
    PYTHONHASHSEED, so a bucket's affinity worker is reproducible and
    survives coordinator restarts.  The max-scoring *live* worker owns
    the bucket; when it dies, ownership falls to the runner-up without
    reshuffling anyone else (the rendezvous property)."""
    h = hashlib.blake2s(f"{bucket_id}|{worker_id}".encode(),
                        digest_size=8)
    return int.from_bytes(h.digest(), "big")


class _Worker:
    """Parent-side handle: process, pipe, budget, in-flight bookkeeping."""

    def __init__(self, worker_id: int):
        self.id = worker_id
        self.proc = None
        self.conn = None
        self.alive = False
        self.restarts = 0
        self.in_flight: dict[int, _Task] = {}
        self.dispatched_at: dict[int, float] = {}
        self.ping_sent: Optional[float] = None
        self.n_devices = 0
        # bucket ids this process has compiled (reset on respawn: a
        # fresh process has cold jit caches)
        self.seen: set[str] = set()


class ProcessCoordinator:
    """Spawn, feed, and supervise a pool of SpGEMM worker processes.

    n_workers:           pool size.
    n_lanes:             device-lane space partitioned over the pool
                         (default: the parent's visible device count).
    cache_path:          shared autotune/quarantine cache file; every
                         worker opens its own ``AutotuneCache`` on it
                         (push-on-quarantine / pull-on-plan-miss make
                         it a coordinator-free shared KV).
    fault_specs:         chaos: a list of picklable ``FaultSpec``s armed
                         in every worker, or a dict ``{worker_id:
                         [specs]}`` for targeted kills.  Re-armed on
                         restart (a respawned worker runs the same
                         binary under the same chaos).
    max_worker_restarts: respawn budget *per worker slot*.
    max_task_retries:    re-dispatch budget per task before it is
                         returned as ``pool_lost`` (guards against a
                         task that kills every worker it touches).
    affinity_spill:      backlog depth at a bucket's affinity worker
                         past which its task may spill to a cold idle
                         worker (recompiling there beats waiting);
                         below it, tasks queue for the worker that
                         already owns the bucket's compiled plan.
    task_timeout_s:      age at which an in-flight task declares its
                         worker hung (None disables).
    heartbeat_timeout_s: unanswered-ping age at which an *idle* worker
                         is declared dead.
    start_timeout_s:     max wait for a spawned worker's ready
                         handshake.
    """

    def __init__(self, n_workers: int, *,
                 n_lanes: Optional[int] = None,
                 cache_path: Optional[str] = None,
                 engine: str = "auto",
                 fault_specs: Union[Sequence[fi.FaultSpec],
                                    dict, None] = None,
                 fault_seed: int = 0,
                 max_worker_restarts: int = 3,
                 max_task_retries: int = 3,
                 affinity_spill: int = 2,
                 task_timeout_s: Optional[float] = 120.0,
                 heartbeat_timeout_s: float = 10.0,
                 start_timeout_s: float = 120.0):
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        if n_lanes is None:
            import jax
            n_lanes = len(jax.devices())
        self.n_lanes = max(1, int(n_lanes))
        self.cache_path = cache_path
        self.engine = engine
        self.fault_specs = fault_specs
        self.fault_seed = fault_seed
        self.max_worker_restarts = max_worker_restarts
        self.max_task_retries = max_task_retries
        self.affinity_spill = max(int(affinity_spill), 1)
        self.task_timeout_s = task_timeout_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.start_timeout_s = start_timeout_s
        self._ctx = mp.get_context("spawn")
        self._workers = [_Worker(i) for i in range(n_workers)]
        self._queue: collections.deque[_Task] = collections.deque()
        self._next_task = 0
        self.events: list[dict] = []  # supervision log (tests assert on it)
        lanes = self._partition(n_workers)
        for w, nl in zip(self._workers, lanes):
            self._spawn(w, nl)
        if not self._alive():
            raise PoolLost("no worker survived startup")

    # -- membership ------------------------------------------------------

    def _alive(self) -> list[_Worker]:
        return [w for w in self._workers if w.alive]

    @property
    def alive_count(self) -> int:
        return len(self._alive())

    def _partition(self, n: int) -> list[int]:
        from repro.runtime.elastic import remesh_lanes
        return [len(r) for r in remesh_lanes(self.n_lanes, max(n, 1))]

    def _specs_for(self, worker_id: int) -> list:
        s = self.fault_specs
        if s is None:
            return []
        if isinstance(s, dict):
            s = s.get(worker_id, [])
        # fresh copies: fire counters must not leak across restarts or
        # into the parent's own spec objects
        return [dataclasses.replace(spec, fires=0) for spec in s]

    def _spawn(self, w: _Worker, n_lanes: int) -> bool:
        init = {
            "sys_path": list(sys.path),
            "cache_path": self.cache_path,
            "n_lanes": n_lanes,
            "fault_specs": self._specs_for(w.id),
            "fault_seed": self.fault_seed,
        }
        parent_conn, child_conn = self._ctx.Pipe()
        proc = self._ctx.Process(target=_worker_main,
                                 args=(child_conn, w.id, init), daemon=True)
        proc.start()
        child_conn.close()  # our copy — EOF must propagate on child death
        w.proc, w.conn = proc, parent_conn
        w.ping_sent = None
        w.seen = set()
        if not parent_conn.poll(self.start_timeout_s):
            self._kill(w)
            self.events.append({"event": "start_timeout", "worker": w.id})
            return False
        try:
            tag, pid, n_dev = parent_conn.recv()
        except (EOFError, OSError):
            self._kill(w)
            self.events.append({"event": "start_died", "worker": w.id})
            return False
        w.alive = tag == "ready"
        w.n_devices = n_dev
        self.events.append({"event": "spawn", "worker": w.id, "pid": pid,
                            "n_lanes": n_lanes})
        return w.alive

    def _kill(self, w: _Worker) -> None:
        w.alive = False
        if w.proc is not None and w.proc.is_alive():
            try:
                os.kill(w.proc.pid, signal.SIGKILL)
            except (OSError, TypeError):
                pass
        if w.proc is not None:
            w.proc.join(timeout=5.0)
        if w.conn is not None:
            try:
                w.conn.close()
            except OSError:
                pass
        w.conn = None

    def _remesh(self) -> None:
        """Re-partition lanes over the live workers and tell each one.

        The elastic shrink/grow step: a 4-worker pool losing one spreads
        the lane space over the remaining 3; a restart spreads it back."""
        alive = self._alive()
        if not alive:
            return
        lanes = self._partition(len(alive))
        for w, nl in zip(alive, lanes):
            try:
                w.conn.send(("remesh", nl))
            except (OSError, ValueError):
                pass  # a dying worker is caught by the next poll
        self.events.append({"event": "remesh", "workers": len(alive),
                            "lanes": lanes})

    def _on_worker_lost(self, w: _Worker, why: str,
                        out: list) -> None:
        """Requeue a dead worker's tasks, respawn within budget, remesh."""
        orphans = list(w.in_flight.values())
        w.in_flight.clear()
        w.dispatched_at.clear()
        self._kill(w)
        self.events.append({"event": "worker_lost", "worker": w.id,
                            "why": why, "orphans": [t.id for t in orphans]})
        if w.restarts < self.max_worker_restarts:
            w.restarts += 1
            n = self._partition(len(self._alive()) + 1)[-1]
            if self._spawn(w, n):
                self.events.append({"event": "restart", "worker": w.id,
                                    "n": w.restarts})
        # a killed worker's buckets re-run on survivors — preferring a
        # different worker, so a task that keeps killing its host makes
        # progress instead of chasing the respawn
        for t in orphans:
            t.tries += 1
            if t.tries > self.max_task_retries:
                self.events.append({"event": "task_abandoned", "task": t.id})
                out.append((t.id, {"pool_lost": True,
                                   "why": f"retries exhausted ({why})"}))
            elif not self._dispatch(t, avoid=w.id):
                self._queue.append(t)
        self._remesh()

    # -- dispatch --------------------------------------------------------

    def _dispatch(self, t: _Task, avoid: Optional[int] = None,
                  prefer: Optional[int] = None) -> bool:
        """Route one task to a worker; False keeps it queued.

        Bucketed tasks (flushes and warms) go to their **affinity
        worker** (rendezvous hash over the live set) — the process that
        has, or will, compile that bucket.  When the affinity worker is
        busy, another *idle* worker that already compiled the bucket may
        take it; a cold idle worker only gets it once the affinity
        worker's backlog reaches ``affinity_spill`` (a recompile then
        beats waiting).  Otherwise the task stays queued — on a pool
        whose workers share cores, spraying one bucket across processes
        multiplies compiles without adding throughput (the old w4 <
        w2 inversion).  Bucketless tasks fall back to least-loaded."""
        alive = [w for w in self._alive() if w.id != avoid] or self._alive()
        if not alive:
            return False
        w = None
        preferred = [x for x in alive if x.id == prefer]
        bid = t.bucket_id
        if preferred:
            w = preferred[0]
        elif bid is not None:
            aff = max(alive, key=lambda x: _hrw(bid, x.id))
            if not aff.in_flight:
                w = aff
            else:
                warm_idle = [x for x in alive
                             if bid in x.seen and not x.in_flight]
                idle = [x for x in alive if not x.in_flight]
                if warm_idle:
                    w = max(warm_idle, key=lambda x: _hrw(bid, x.id))
                elif idle and len(aff.in_flight) >= self.affinity_spill:
                    w = max(idle, key=lambda x: _hrw(bid, x.id))
                else:
                    return False  # hold for the worker that owns it
        else:
            w = min(alive, key=lambda x: len(x.in_flight))
        try:
            w.conn.send(("task", t.id, t.payload))
        except (OSError, ValueError):
            return False  # worker died under us; poll will reap it
        w.in_flight[t.id] = t
        w.dispatched_at[t.id] = time.monotonic()
        if bid is not None:
            w.seen.add(bid)
        return True

    def _drain_queue(self) -> None:
        # scan the whole queue, not just the head: affinity can block
        # the head task (its owner is busy) while a later task's owner
        # sits idle
        if not self._queue:
            return
        held = []
        while self._queue:
            t = self._queue.popleft()
            if not self._dispatch(t):
                held.append(t)
        self._queue.extend(held)

    def submit(self, payload: dict,
               prefer: Optional[int] = None) -> int:
        """Queue one flush task; returns its task id.

        ``prefer`` pins the task to a worker id when that worker is
        live (tests use it to sequence cross-worker scenarios; the
        default is least-loaded).  Raises :class:`PoolLost` when no
        worker is live and none can be respawned — the caller's
        in-process ladder takes over."""
        if not self._alive():
            raise PoolLost("no live workers")
        t = _Task(self._next_task, payload)
        self._next_task += 1
        if not self._dispatch(t, prefer=prefer):
            self._queue.append(t)
        return t.id

    @property
    def in_flight(self) -> int:
        return len(self._queue) + sum(len(w.in_flight)
                                      for w in self._workers)

    # -- supervision loop ------------------------------------------------

    def _handle(self, w: _Worker, msg: tuple, out: list) -> None:
        tag = msg[0]
        if tag == "pong":
            w.ping_sent = None
            return
        if tag == "result":
            _, tid, res = msg
            t = w.in_flight.pop(tid, None)
            w.dispatched_at.pop(tid, None)
            if t is not None:
                out.append((tid, res))
            return
        if tag == "error":
            _, tid, kind, message = msg
            t = w.in_flight.pop(tid, None)
            w.dispatched_at.pop(tid, None)
            self.events.append({"event": "task_error", "task": tid,
                                "worker": w.id, "kind": kind})
            if t is not None:
                out.append((tid, {"error": {"kind": kind,
                                            "message": message}}))

    def _check_hangs(self, out: list) -> None:
        if self.task_timeout_s is None:
            return
        now = time.monotonic()
        for w in self._alive():
            if w.dispatched_at and \
                    now - min(w.dispatched_at.values()) > self.task_timeout_s:
                self._on_worker_lost(w, "task timeout", out)

    def poll(self, timeout: float = 0.0) -> list[tuple[int, dict]]:
        """Drain finished tasks: [(task_id, result_dict)].

        A result dict is the worker's ``{"outcomes": ..., "flush": ...}``
        on success, ``{"error": {...}}`` on an infrastructural failure
        inside a live worker, or ``{"pool_lost": True, ...}`` when the
        task ran out of workers to die on.  Death, hang, and restart
        handling all happen inside this call."""
        out: list[tuple[int, dict]] = []
        deadline = time.monotonic() + max(0.0, timeout)
        while True:
            conns = {w.conn: w for w in self._alive()}
            if not conns:
                # total pool loss: hand every remaining task back
                for t in list(self._queue):
                    out.append((t.id, {"pool_lost": True,
                                       "why": "no live workers"}))
                self._queue.clear()
                return out
            wait_s = max(0.0, deadline - time.monotonic())
            ready = mpc.wait(list(conns), timeout=wait_s)
            for conn in ready:
                w = conns[conn]
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    code = w.proc.exitcode if w.proc is not None else None
                    self._on_worker_lost(w, f"pipe EOF (exit {code})", out)
                    continue
                self._handle(w, msg, out)
            self._check_hangs(out)
            self._drain_queue()
            if out or time.monotonic() >= deadline:
                return out

    def heartbeat(self) -> None:
        """Ping idle workers; reap the ones that stopped answering.

        Busy workers are covered by ``task_timeout_s`` — a worker
        grinding a flush cannot answer pings and must not die for it."""
        now = time.monotonic()
        for w in self._alive():
            if w.in_flight:
                continue
            if w.ping_sent is None:
                try:
                    w.conn.send(("ping", now))
                    w.ping_sent = now
                except (OSError, ValueError):
                    self._on_worker_lost(w, "ping send failed", [])
            elif now - w.ping_sent > self.heartbeat_timeout_s:
                self._on_worker_lost(w, "heartbeat timeout", [])

    # -- lifecycle -------------------------------------------------------

    def shutdown(self) -> None:
        for w in self._workers:
            if w.alive and w.conn is not None:
                try:
                    w.conn.send(("stop",))
                except (OSError, ValueError):
                    pass
        for w in self._workers:
            if w.proc is not None:
                w.proc.join(timeout=5.0)
            self._kill(w)

    def __enter__(self) -> "ProcessCoordinator":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
