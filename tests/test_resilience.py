"""Resilience layer: deterministic fault injection, operand validation,
autotune quarantine, retry/deadline/degradation policies, shard-worker
recovery, and the end-to-end chaos test (worker killed mid-flush plus a
10% injected kernel-fault rate -> every request resolves, surviving
results bit-exact against a fault-free run).

Everything here is seeded/virtual-clocked: no real time dependence, no
flaky randomness.  The suite runs on any device count — the CI
``chaos-fast`` lane re-runs it with 8 forced host devices so the
sharded-worker paths are exercised multi-device."""
import dataclasses
import time

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import assume, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core.formats import (InvalidOperand, random_sparse, validate_csr,
                                validate_operands)
from repro.distributed import spgemm_shard as shard
from repro.runtime import faultinject as fi
from repro.serving import spgemm_service as svc


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


@pytest.fixture
def cache(tmp_path):
    return dp.AutotuneCache(str(tmp_path / "autotune.json"))


def _mat(n=48, density=0.02, seed=0, pattern="uniform"):
    return random_sparse(n, n, density, seed=seed, pattern=pattern)


def _dense(csr):
    return np.asarray(csr.to_dense(), np.float64)


# ---------------------------------------------------------------------------
# fault-injection harness
# ---------------------------------------------------------------------------

def test_hooks_are_noops_when_disabled():
    assert fi.active() is None
    fi.fire("dispatch.execute", engine="esc")       # must not raise
    m = _mat(seed=1)
    assert fi.corrupt("dispatch.execute", m) is m   # identity, same object


def test_raise_spec_fires_and_logs():
    with fi.injected(fi.FaultSpec(site="dispatch.execute")) as inj:
        with pytest.raises(fi.InjectedFault) as ei:
            fi.fire("dispatch.execute", engine="esc")
        assert ei.value.site == "dispatch.execute"
        assert inj.events[0]["site"] == "dispatch.execute"
        assert inj.events[0]["engine"] == "esc"
    fi.fire("dispatch.execute")  # uninstalled again on exit


def test_match_filter_and_max_fires():
    spec = fi.FaultSpec(site="shard.worker", match={"device": 1},
                        max_fires=1)
    with fi.injected(spec) as inj:
        fi.fire("shard.worker", device=0)           # wrong device: no fire
        with pytest.raises(fi.InjectedFault):
            fi.fire("shard.worker", device=1)
        fi.fire("shard.worker", device=1)           # max_fires exhausted
        assert spec.fires == 1 and len(inj.events) == 1


def test_rate_is_seed_deterministic():
    def pattern(seed):
        fired = []
        with fi.injected(fi.FaultSpec(site="s", rate=0.3), seed=seed):
            for _ in range(40):
                try:
                    fi.fire("s")
                    fired.append(0)
                except fi.InjectedFault:
                    fired.append(1)
        return fired
    a, b = pattern(7), pattern(7)
    assert a == b                       # same seed -> identical schedule
    assert 0 < sum(a) < 40              # and the rate actually gates
    assert pattern(8) != a              # different seed -> different draw


def test_hang_spec_uses_injected_sleep():
    naps = []
    spec = fi.FaultSpec(site="s", kind="hang", delay_s=2.5)
    with fi.injected(spec, sleep=naps.append) as inj:
        inj.fire("s")
    assert naps == [2.5]


def test_corrupt_nan_and_garbage_are_detectable():
    m = _mat(seed=2)
    out = sg.spgemm_scl_array(m, m)
    with fi.injected(fi.FaultSpec(site="dispatch.execute", kind="nan")):
        bad = fi.corrupt("dispatch.execute", out)
    with pytest.raises(dp.CorruptOutput, match="non-finite"):
        dp.check_result(bad)
    with fi.injected(fi.FaultSpec(site="dispatch.execute", kind="garbage")):
        bad = fi.corrupt("dispatch.execute", out)
    with pytest.raises(dp.CorruptOutput, match="out of range"):
        dp.check_result(bad)
    dp.check_result(out)  # the pristine result still screens clean


def test_injected_execute_fault_reaches_dispatch(cache):
    m = _mat(seed=3)
    p = dp.plan(m, m, engine="esc", cache=cache)
    with fi.injected(fi.FaultSpec(site="dispatch.execute",
                                  match={"engine": "esc"})):
        with pytest.raises(fi.InjectedFault):
            dp.execute(p, m, m)
    np.testing.assert_allclose(_dense(dp.execute(p, m, m)),
                               _dense(sg.spgemm_scl_array(m, m)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# operand validation at the boundary
# ---------------------------------------------------------------------------

def test_validate_csr_names_the_bad_field():
    m = _mat(seed=4)
    nnz = int(np.asarray(m.indptr)[-1])
    assert nnz > 0
    validate_csr(m, "A")  # pristine operand passes

    indptr = np.asarray(m.indptr).copy()
    indptr[1] = indptr[-1] + 5   # non-monotonic
    with pytest.raises(InvalidOperand, match="non-monotonic") as ei:
        validate_csr(dataclasses.replace(m, indptr=jnp.asarray(indptr)), "A")
    assert ei.value.field == "A.indptr"

    idx = np.asarray(m.indices).copy()
    idx[0] = m.n_cols + 3        # out-of-range column
    with pytest.raises(InvalidOperand, match="out of range") as ei:
        validate_csr(dataclasses.replace(m, indices=jnp.asarray(idx)), "B")
    assert ei.value.field == "B.indices"

    data = np.asarray(m.data).copy()
    data[0] = np.nan             # non-finite payload
    with pytest.raises(InvalidOperand, match="non-finite") as ei:
        validate_csr(dataclasses.replace(m, data=jnp.asarray(data)), "A")
    assert ei.value.field == "A.data"


def test_validate_operands_checks_inner_dims():
    with pytest.raises(InvalidOperand, match="inner dims") as ei:
        validate_operands(_mat(n=32), _mat(n=48))
    assert ei.value.field == "B.shape"


def test_service_submit_rejects_malformed_operand(cache):
    clock = VirtualClock()
    service = svc.SpGemmService(cache=cache, clock=clock, max_batch=4)
    m = _mat(seed=5)
    data = np.asarray(m.data).copy()
    data[0] = np.inf
    bad = dataclasses.replace(m, data=jnp.asarray(data))
    with pytest.raises(InvalidOperand, match="A.data"):
        service.submit(bad, m)
    # the poisoned request never entered a queue or burned an id
    assert service.pending == 0 and service._next_id == 0


def test_dispatch_plan_rejects_malformed_operand(cache):
    m = _mat(seed=6)
    idx = np.asarray(m.indices).copy()
    idx[0] = -3
    bad = dataclasses.replace(m, indices=jnp.asarray(idx))
    with pytest.raises(InvalidOperand, match="A.indices"):
        dp.plan(bad, m, engine="auto", cache=cache)


# hypothesis property tests are defined only when the package imports
# (CI installs the dev deps; a bare checkout still runs everything else)
if HAVE_HYPOTHESIS:
    @given(n=st.integers(2, 48), density=st.floats(0.005, 0.3),
           seed=st.integers(0, 10_000),
           pattern=st.sampled_from(["uniform", "powerlaw", "banded"]))
    @settings(max_examples=30, deadline=None)
    def test_random_sparse_always_validates(n, density, seed, pattern):
        validate_csr(random_sparse(n, n, density, seed=seed,
                                   pattern=pattern))

    @given(n=st.integers(4, 32), seed=st.integers(0, 10_000),
           slot=st.integers(0, 10_000),
           mutation=st.sampled_from(["indptr", "indices", "data"]))
    @settings(max_examples=30, deadline=None)
    def test_single_field_corruption_is_always_caught(n, seed, slot,
                                                      mutation):
        """Any single-field structural corruption of a valid operand
        must be rejected, naming the corrupted field."""
        m = random_sparse(n, n, 0.2, seed=seed)
        nnz = int(np.asarray(m.indptr)[-1])
        assume(nnz > 0)
        i = slot % nnz
        if mutation == "indptr":
            arr = np.asarray(m.indptr).copy()
            arr[1 + (slot % m.n_rows)] = -1  # below start: non-monotonic
            bad = dataclasses.replace(m, indptr=jnp.asarray(arr))
        elif mutation == "indices":
            arr = np.asarray(m.indices).copy()
            arr[i] = m.n_cols + (slot % 7)
            bad = dataclasses.replace(m, indices=jnp.asarray(arr))
        else:
            arr = np.asarray(m.data).copy()
            arr[i] = np.nan
            bad = dataclasses.replace(m, data=jnp.asarray(arr))
        with pytest.raises(InvalidOperand) as ei:
            validate_csr(bad, "A")
        assert ei.value.field.startswith("A.")


# ---------------------------------------------------------------------------
# autotune quarantine
# ---------------------------------------------------------------------------

def test_quarantine_roundtrip_and_version_bump(cache):
    key = "shape=(48,48)x(48,48)|nnz=64x64"
    v0 = cache.version
    cache.put(key, "esc", "autotune")
    cache.quarantine(key, "esc", None, reason="kernel crashed")
    assert cache.is_quarantined(key, "esc")
    assert cache.is_quarantined(key, "esc", None)
    assert not cache.is_quarantined(key, "spz-fused", "xla")
    assert ("esc", None) in cache.quarantined(key)
    assert cache.get(key) is None      # the poisoned selection was dropped
    assert cache.version > v0          # memoized plans invalidated
    # quarantine survives a disk round-trip (fresh cache object, same file)
    reread = dp.AutotuneCache(cache.path)
    assert reread.is_quarantined(key, "esc")


def test_quarantine_merges_across_processes(cache, tmp_path):
    key = "k"
    other = dp.AutotuneCache(cache.path)
    cache.quarantine(key, "esc", None)
    other.quarantine(key, "spz-fused", "xla")   # concurrent writer
    merged = dp.AutotuneCache(cache.path)
    assert merged.is_quarantined(key, "esc")
    assert merged.is_quarantined(key, "spz-fused", "xla")


def test_refresh_pulls_entries_flushed_by_another_process(cache):
    """The "pull" half of the cross-process cache protocol: another
    process's flush becomes visible via refresh(), with a version bump
    exactly when something changed."""
    other = dp.AutotuneCache(cache.path)
    cache.put("mine", "esc", "heuristic")      # load + flush our view
    v0 = cache.version
    other.put("theirs", "spz-fused", "autotune")
    other.quarantine("poisoned", "esc", None)
    assert cache.get("theirs") is None         # stale view so far
    assert cache.refresh() is True
    assert cache.get("theirs")["engine"] == "spz-fused"
    assert cache.is_quarantined("poisoned", "esc")
    assert cache.version > v0                  # memoized plans invalidated
    v1 = cache.version
    assert cache.refresh() is False            # idempotent: nothing new
    assert cache.version == v1


def test_plan_miss_pulls_quarantine_pushed_by_sibling(cache, tmp_path):
    """Pull-on-plan-miss: a combo poisoned by a sibling process is never
    selected by this process, even on its very first plan of the
    bucket."""
    m = _mat(seed=21)
    # what would this process pick, unpoisoned?
    probe = dp.plan(m, m, engine="auto",
                    cache=dp.AutotuneCache(str(tmp_path / "probe.json")))
    # force our cache to load its (still-empty) view of the file FIRST
    assert len(cache) == 0
    # ...then a sibling process poisons that combo (push-on-quarantine)
    sibling = dp.AutotuneCache(cache.path)
    sibling.quarantine(probe.cache_key, probe.engine, probe.backend,
                       reason="crashed in sibling")
    p = dp.plan(m, m, engine="auto", cache=cache)
    assert (p.engine, p.backend) != (probe.engine, probe.backend)
    assert p.rule == "quarantine-fallback"


def test_flush_lock_timeout_skips_never_stalls(cache, tmp_path):
    """Satellite hardening: a hung — not dead — holder of the autotune
    file lock costs a *skipped flush*, never a stalled serving process.
    The holder hangs via an injected ``hang``-kind fault fired while it
    holds the flock; the contender's put() must return within its lock
    timeout with the write skipped, then land the entry once the lock
    frees."""
    import threading
    try:
        import fcntl  # noqa: F401  (lock contention needs flock)
    except ImportError:
        pytest.skip("no fcntl on this platform")

    holder = dp.AutotuneCache(cache.path)
    holding = threading.Event()
    release = threading.Event()

    def hold_and_hang(_delay):
        holding.set()
        release.wait(timeout=30.0)

    def run_holder():
        # the hang fires at the autotune.flush site, *after* the flock
        # is taken (see AutotuneCache._flush ordering)
        with fi.injected(fi.FaultSpec(site="autotune.flush", kind="hang",
                                      delay_s=1.0, max_fires=1),
                         sleep=hold_and_hang):
            holder.put("held", "esc", "heuristic")

    t = threading.Thread(target=run_holder, daemon=True)
    t.start()
    assert holding.wait(timeout=10.0)

    contender = dp.AutotuneCache(cache.path, lock_timeout_s=0.2)
    t0 = time.monotonic()
    contender.put("contended", "spz-fused", "heuristic")
    elapsed = time.monotonic() - t0
    assert elapsed < 5.0, f"put stalled {elapsed:.1f}s behind a hung holder"
    # the flush was skipped, not silently dropped: the entry stayed in
    # memory and the file does not have it yet
    assert contender.get("contended") is not None
    assert dp.AutotuneCache(cache.path).get("contended") is None

    release.set()
    t.join(timeout=30.0)
    # lock free again: the next flush lands both writers' entries
    contender.put("contended2", "esc", "heuristic")
    merged = dp.AutotuneCache(cache.path)
    assert merged.get("contended") is not None
    assert merged.get("contended2") is not None
    assert merged.get("held") is not None


def test_autotune_sweep_survives_crashing_engine(cache):
    """A candidate that raises mid-sweep is quarantined and the sweep
    finishes on the healthy engines — the satellite's crashing fake
    engine, registered for the duration of the test."""
    def crashy(A, B, **kw):
        raise RuntimeError("synthetic kernel crash")
    dp.register_engine("crashy", crashy, measure=True,
                       description="always raises (test engine)")
    try:
        m = _mat(seed=7)
        p = dp.plan(m, m, engine="auto", autotune=True, cache=cache)
        assert p.source == "autotune" and p.engine != "crashy"
        assert cache.is_quarantined(p.cache_key, "crashy")
        # and the winner actually runs
        np.testing.assert_allclose(_dense(dp.execute(p, m, m)),
                                   _dense(sg.spgemm_scl_array(m, m)),
                                   rtol=1e-4, atol=1e-4)
    finally:
        dp._REGISTRY.pop("crashy", None)


def test_plan_routes_around_quarantined_selection(cache):
    m = _mat(seed=8)
    p0 = dp.plan(m, m, engine="auto", cache=cache)
    cache.quarantine(p0.cache_key, p0.engine, p0.backend,
                     reason="poisoned by test")
    p1 = dp.plan(m, m, engine="auto", cache=cache)
    assert p1.engine != p0.engine or p1.backend != p0.backend
    assert p1.rule == "quarantine-fallback" or p1.source == "cache"


def test_measure_fault_site_quarantines_mid_sweep(cache):
    """The same mid-sweep hardening, driven through the injection
    harness instead of a fake engine: the measured candidate that dies
    is quarantined, the sweep continues."""
    m = _mat(seed=9)
    with fi.injected(fi.FaultSpec(site="dispatch.measure",
                                  match={"engine": "esc"})):
        p = dp.plan(m, m, engine="auto", autotune=True, cache=cache)
    assert p.source == "autotune" and p.engine != "esc"
    assert cache.is_quarantined(p.cache_key, "esc")


# ---------------------------------------------------------------------------
# retry / deadline / degradation (execute_resilient)
# ---------------------------------------------------------------------------

def _nosleep_policy(**kw):
    kw.setdefault("sleep", lambda s: None)
    return dp.RetryPolicy(**kw)


def test_execute_resilient_retries_transient_fault(cache):
    m = _mat(seed=10)
    p = dp.plan(m, m, engine="esc", cache=cache)
    naps = []
    policy = _nosleep_policy(sleep=naps.append)
    with fi.injected(fi.FaultSpec(site="dispatch.execute", max_fires=2)):
        out, report = dp.execute_resilient(p, m, m, policy=policy,
                                           cache=cache)
    assert report.tier == 0 and report.attempts == 3
    assert report.tier_label == "planned" and not report.degraded
    assert naps == [policy.backoff_s(1), policy.backoff_s(2)]  # exponential
    assert naps[1] == naps[0] * policy.backoff_factor
    np.testing.assert_allclose(_dense(out),
                               _dense(sg.spgemm_scl_array(m, m)),
                               rtol=1e-4, atol=1e-4)


def test_execute_resilient_degrades_and_quarantines(cache):
    m = _mat(seed=11)
    p = dp.plan(m, m, engine="spz-fused", backend="xla", cache=cache)
    # the planned engine fails persistently; first healthy rung is esc
    with fi.injected(
            fi.FaultSpec(site="dispatch.execute",
                         match={"engine": "spz-fused"})):
        out, report = dp.execute_resilient(p, m, m,
                                           policy=_nosleep_policy(),
                                           cache=cache)
    assert report.degraded and report.engine == "esc"
    assert report.tier_label == "degraded:esc"
    assert cache.is_quarantined(p.cache_key, "spz-fused", p.backend)
    assert ("spz-fused", p.backend) in report.quarantined
    np.testing.assert_allclose(_dense(out),
                               _dense(sg.spgemm_scl_array(m, m)),
                               rtol=1e-4, atol=1e-4)


def test_execute_resilient_catches_silent_corruption(cache):
    """NaN output without an exception must count as a failed attempt,
    not be served."""
    m = _mat(seed=12)
    p = dp.plan(m, m, engine="esc", cache=cache)
    with fi.injected(fi.FaultSpec(site="dispatch.execute", kind="nan",
                                  max_fires=1)):
        out, report = dp.execute_resilient(p, m, m,
                                           policy=_nosleep_policy(),
                                           cache=cache)
    assert report.attempts == 2 and report.tier == 0
    assert "CorruptOutput" in report.errors[0]
    dp.check_result(out)


def test_execute_resilient_deadline(cache):
    m = _mat(seed=13)
    p = dp.plan(m, m, engine="esc", cache=cache)
    clock = VirtualClock()
    policy = _nosleep_policy(deadline_s=1.0, clock=clock,
                             sleep=lambda s: clock.advance(10.0))
    with fi.injected(fi.FaultSpec(site="dispatch.execute")):
        with pytest.raises(dp.DeadlineExceeded):
            dp.execute_resilient(p, m, m, policy=policy, cache=cache)


def test_execute_resilient_exhausts_all_tiers(cache):
    m = _mat(seed=14)
    p = dp.plan(m, m, engine="esc", cache=cache)
    with fi.injected(fi.FaultSpec(site="dispatch.execute")):  # every engine
        with pytest.raises(dp.ExhaustedFallbacks) as ei:
            dp.execute_resilient(p, m, m, policy=_nosleep_policy(),
                                 cache=cache)
    report = ei.value.report
    # every rung of the ladder was tried, retried, and quarantined
    assert report.attempts == 3 * 3
    assert len(report.quarantined) == 3
    for eng, bk in report.quarantined:
        assert cache.is_quarantined(p.cache_key, eng, bk)


# ---------------------------------------------------------------------------
# shard-worker loss and recovery
# ---------------------------------------------------------------------------

def _batch(seeds, n=64, density=0.02):
    from repro.core.formats import batch_csr
    mats = [_mat(n=n, density=density, seed=s) for s in seeds]
    return mats, batch_csr(mats)


def test_worker_kill_recovers_bit_exact(cache):
    """Kill one shard worker mid-flush: its lanes re-run on a survivor
    (or the flush is retried whole on one device) and the assembled
    results are bit-identical to the fault-free run."""
    mats, A = _batch([1, 2, 3, 4])
    sp = shard.plan_sharded(A, A, "esc", cache=cache)
    want = shard.execute_sharded(sp, A, A)
    kill = shard.kill_worker_spec(0)
    with fi.injected(kill) as inj:
        if sp.n_dev == 1:
            # nowhere to migrate: the loss must surface for the caller
            # (the serving layer's retry tier) to handle
            with pytest.raises(shard.WorkerLost):
                shard.execute_sharded(sp, A, A)
            assert kill.fires == 1
            got = shard.execute_sharded(sp, A, A)  # kill spec exhausted
        else:
            got = shard.execute_sharded(sp, A, A)
            assert any(e["site"] == "shard.worker" for e in inj.events)
    for i in range(len(mats)):
        assert np.array_equal(np.asarray(want[i].indptr),
                              np.asarray(got[i].indptr))
        assert np.array_equal(np.asarray(want[i].to_dense()),
                              np.asarray(got[i].to_dense()))


def test_all_workers_dead_raises(cache):
    mats, A = _batch([5, 6])
    sp = shard.plan_sharded(A, A, "esc", cache=cache)
    specs = [shard.kill_worker_spec(d, max_fires=None)
             for d in range(sp.n_dev)]
    with fi.injected(*specs):
        with pytest.raises(shard.WorkerLost):
            shard.execute_sharded(sp, A, A)


# ---------------------------------------------------------------------------
# the chaos test (the PR's acceptance gate)
# ---------------------------------------------------------------------------

def _run_traffic(cache, specs=(), seed=0, n_req=16, policy=None):
    """Drive a fixed synthetic request stream through a fresh service,
    optionally under injected chaos; returns the service."""
    clock = VirtualClock()
    service = svc.SpGemmService(cache=cache, clock=clock, max_batch=4,
                                flush_timeout=1.0,
                                policy=policy or dp.RetryPolicy(
                                    max_attempts=5, backoff_base_s=0.0))
    classes = [(32, 0.02, "uniform"), (48, 0.05, "uniform"),
               (48, 0.008, "powerlaw"), (64, 0.03, "banded")]
    mats = [_mat(n=c[0], density=c[1], pattern=c[2], seed=i)
            for i, c in enumerate(classes)]
    rng = np.random.default_rng(3)
    stream = [mats[int(rng.integers(len(mats)))] for _ in range(n_req)]
    if specs:
        with fi.injected(*specs, seed=seed):
            for m in stream:
                service.submit(m, m, now=clock.advance(0.01))
            service.drain()
    else:
        for m in stream:
            service.submit(m, m, now=clock.advance(0.01))
        service.drain()
    return service


def test_chaos_worker_kill_plus_kernel_faults(tmp_path):
    """The acceptance scenario: a shard worker is killed mid-flush AND
    batched kernel launches fail at a 10% injected rate.  Every request
    must resolve (result or structured dead letter — nothing silently
    dropped), availability must clear 99%, and every surviving request
    must be bit-exact against the fault-free run."""
    ref = _run_traffic(dp.AutotuneCache(str(tmp_path / "ref.json")))
    assert len(ref.completed) == 16 and not ref.dead_letters

    chaos = _run_traffic(
        dp.AutotuneCache(str(tmp_path / "chaos.json")),
        specs=(fi.FaultSpec(site="kernel.batched", kind="raise", rate=0.10),
               shard.kill_worker_spec(0)),
        seed=11)

    # nothing silently dropped: every submitted id resolves
    for rid in range(16):
        r = chaos.lookup(rid)
        assert r.done, f"request {rid} neither completed nor dead-lettered"
        assert (r.result is not None) != (r.error is not None)
    assert len(chaos.completed) + len(chaos.dead_letters) == 16

    stats = chaos.stats()
    assert stats["availability"] >= 0.99, stats

    # surviving requests are bit-exact vs the fault-free run: transient
    # same-tier retries and worker re-bucketing change *where* a lane
    # ran, never *what* it computed
    want = {r.id: _dense(r.result) for r in ref.completed}
    for r in chaos.completed:
        if r.tier == "planned":
            assert np.array_equal(_dense(r.result), want[r.id]), r.id
        else:  # a degraded tier runs a different engine: exact-ish only
            np.testing.assert_allclose(_dense(r.result), want[r.id],
                                       rtol=1e-4, atol=1e-4)
    # with 5 attempts against a 10% fault rate, the planned tier
    # absorbs the chaos: no dead letters and (near-)no degradation
    assert stats["availability"] == 1.0
    assert stats["n_degraded"] == 0, [r.tier for r in chaos.completed]
    # and the kill actually happened — the chaos was real
    assert any(f.attempts > 1 for f in chaos.flush_log)


def test_chaos_total_engine_failure_dead_letters_with_structure(tmp_path):
    """When every tier including per-request isolation fails, requests
    dead-letter with structured errors instead of raising out of the
    service or vanishing."""
    cache = dp.AutotuneCache(str(tmp_path / "dead.json"))
    service = _run_traffic(
        cache, n_req=4,
        specs=(fi.FaultSpec(site="kernel.batched"),
               fi.FaultSpec(site="dispatch.execute")),
        policy=dp.RetryPolicy(max_attempts=2, backoff_base_s=0.0))
    assert not service.completed
    assert len(service.dead_letters) == 4
    for r in service.dead_letters:
        assert r.error is not None and r.error.stage == "isolate"
        assert r.error.id == r.id and r.error.attempts >= 1
        assert "InjectedFault" in r.error.kind
        assert service.lookup(r.id) is r
    stats = service.stats()
    assert stats["availability"] == 0.0
    assert stats["n_dead_letters"] == 4
    rec = service.flush_log[-1]
    assert rec.tier == "isolated" and rec.n_failed >= 1 and rec.errors


def test_chaos_persistent_kernel_fault_degrades_not_fails(tmp_path):
    """A batched-kernel fault that never clears forces the service down
    the ladder: the flush ends up isolated per request on the reference
    engine, every request still completes, and the flush record shows
    the degradation."""
    cache = dp.AutotuneCache(str(tmp_path / "degrade.json"))
    service = _run_traffic(
        cache, n_req=4,
        specs=(fi.FaultSpec(site="kernel.batched"),),
        policy=dp.RetryPolicy(max_attempts=2, backoff_base_s=0.0))
    assert len(service.completed) == 4 and not service.dead_letters
    assert all(r.tier == "isolated" for r in service.completed)
    assert service.stats()["availability"] == 1.0
    assert service.stats()["n_degraded"] == 4
    # the planned combo was quarantined for this bucket
    assert any(rec.tier == "isolated" for rec in service.flush_log)
    for m in [r.A for r in service.completed]:
        key = dp.cache_key(m, m, backend="auto")
        if cache.quarantined(key):
            break
    else:
        pytest.fail("no bucket was quarantined")
