"""Sweeps for the flash-attention and grouped-matmul Pallas kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.grouped_matmul import grouped_matmul_pallas
from repro.kernels.ref import grouped_matmul_ref, mha_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,Sq,Skv,H,KVH,hd,causal,window", [
    (2, 64, 64, 4, 2, 16, True, 0),
    (1, 96, 96, 8, 1, 32, True, 32),
    (2, 48, 64, 4, 4, 16, True, 0),     # q shorter than kv (chunked prefill)
    (1, 64, 64, 2, 2, 8, False, 0),     # bidirectional (encoder)
    (1, 128, 128, 4, 1, 64, True, 0),   # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, Sq, Skv, H, KVH, hd, causal, window,
                               dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, Skv, KVH, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, Skv, KVH, hd), jnp.float32)
    ref = mha_ref(q, k, v, causal=causal, window=window)
    out = flash_attention_pallas(q.astype(dtype), k.astype(dtype),
                                 v.astype(dtype), causal=causal,
                                 window=window, bq=32, bk=16)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("E,D,F,sizes", [
    (4, 16, 32, [8, 16, 0, 24]),
    (3, 8, 8, [8, 8, 8]),
    (5, 32, 16, [0, 0, 40, 8, 0]),
    (2, 64, 128, [32, 0]),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_sweep(E, D, F, sizes, dtype):
    rng = np.random.default_rng(1)
    T = 64
    x = jnp.asarray(rng.standard_normal((T, D)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((E, D, F)).astype(np.float32))
    gs = jnp.asarray(np.array(sizes, np.int32))
    ref = grouped_matmul_ref(x, w, gs)
    out = grouped_matmul_pallas(x.astype(dtype), w.astype(dtype), gs, bt=8)
    tol = 0.1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)
