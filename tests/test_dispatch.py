"""Engine registry, density-aware auto dispatch, and batched execution.

The acceptance contract: ``spgemm(A, B, engine="auto")`` must match the
scl-array oracle everywhere and must pick *different* engines for at least
two density regimes; ``spgemm_batched`` must equal per-matrix results for a
ragged batch. Hypothesis property tests are skipped on a bare checkout.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core.formats import BatchedCSR, batch_csr, random_sparse


def _dense(m):
    return np.asarray(m.to_dense(), np.float64)


@pytest.fixture
def cache(tmp_path):
    """Per-test autotune cache — keeps tests off the user-level disk cache."""
    return dp.AutotuneCache(str(tmp_path / "autotune.json"))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_all_paper_engines():
    names = set(dp.available_engines())
    assert {"scl-array", "scl-hash", "esc", "spz", "spz-rsort"} <= names


def test_register_and_unknown_engine():
    spec = dp.register_engine("test-dummy", sg.spgemm_scl_array,
                              description="test-only")
    try:
        assert dp.get_engine("test-dummy") is spec
        A = random_sparse(16, 16, 0.05, seed=0)
        out = dp.spgemm(A, A, engine="test-dummy")
        np.testing.assert_allclose(_dense(out),
                                   _dense(sg.spgemm_scl_array(A, A)))
    finally:
        dp._REGISTRY.pop("test-dummy", None)
    with pytest.raises(ValueError, match="unknown engine"):
        dp.get_engine("test-dummy")


# ---------------------------------------------------------------------------
# auto selection
# ---------------------------------------------------------------------------

# (regime, generator args) spanning the heuristic table's density regimes
REGIMES = {
    "tiny": dict(n=24, density=0.002, pattern="uniform"),
    "dense": dict(n=64, density=0.05, pattern="uniform"),
    "skewed": dict(n=96, density=0.02, pattern="powerlaw"),
    "mid": dict(n=96, density=0.008, pattern="banded"),
}


def _regime_matrix(spec, seed=3):
    return random_sparse(spec["n"], spec["n"], spec["density"], seed=seed,
                         pattern=spec["pattern"])


@pytest.mark.parametrize("regime", sorted(REGIMES))
def test_auto_matches_oracle_per_regime(regime, cache):
    A = _regime_matrix(REGIMES[regime])
    want = _dense(sg.spgemm_scl_array(A, A))
    got = _dense(dp.spgemm(A, A, engine="auto", cache=cache))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_auto_selects_different_engines_across_regimes():
    chosen = {r: dp.explain(_regime_matrix(s), _regime_matrix(s))["engine"]
              for r, s in REGIMES.items()}
    assert len(set(chosen.values())) >= 2, chosen


def test_explain_reports_features_and_rule():
    A = _regime_matrix(REGIMES["dense"])
    info = dp.explain(A, A)
    assert info["engine"] in dp.available_engines()
    assert {"density", "total_work", "avg_work_per_row"} <= set(
        info["features"])
    assert info["cache_key"] == dp.cache_key(A, A)


def test_custom_rules_override():
    A = _regime_matrix(REGIMES["dense"])
    rules = (dp.HeuristicRule("always-hash", lambda f: True, "scl-hash"),)
    assert dp.choose_engine(dp.extract_features(A, A), rules) == \
        ("scl-hash", "always-hash")


def test_custom_rules_bypass_cache(cache):
    """A cached default-rules plan must not shadow caller rules, and a
    custom-rules selection must not be written into the cache."""
    A = _regime_matrix(REGIMES["dense"])  # default rules pick esc
    dp.spgemm(A, A, engine="auto", cache=cache)
    assert cache.get(dp.cache_key(A, A))["engine"] == "esc"
    rules = (dp.HeuristicRule("always-hash", lambda f: True, "scl-hash"),)
    out = dp.spgemm(A, A, engine="auto", cache=cache, rules=rules)
    np.testing.assert_allclose(_dense(out),
                               _dense(sg.spgemm_scl_array(A, A)),
                               rtol=1e-4, atol=1e-4)
    # cache entry untouched by the custom-rules call
    assert cache.get(dp.cache_key(A, A)) == {"engine": "esc",
                                             "source": "heuristic"}


def test_auto_drops_engine_specific_kwargs(cache):
    """spz kwargs must not crash an auto run that selects esc (and vice
    versa); an explicitly named engine stays strict."""
    A = _regime_matrix(REGIMES["dense"])  # auto -> esc
    out = dp.spgemm(A, A, engine="auto", cache=cache, R=16, backend="xla")
    np.testing.assert_allclose(_dense(out),
                               _dense(sg.spgemm_scl_array(A, A)),
                               rtol=1e-4, atol=1e-4)
    with pytest.raises(TypeError):
        dp.spgemm(A, A, engine="esc", R=16)
    # batched: esc-only kwarg survives auto->spz-family remap
    mats = _ragged_batch()
    b = batch_csr(mats)
    out = dp.spgemm_batched(b, b, engine="auto", cache=cache,
                            cap_products=1 << 16)
    for i, m in enumerate(mats):
        np.testing.assert_allclose(_dense(out[i]),
                                   _dense(sg.spgemm_scl_array(m, m)),
                                   rtol=1e-4, atol=1e-4)


def test_inner_dim_mismatch_raises():
    A = random_sparse(8, 9, 0.1, seed=0)
    with pytest.raises(ValueError, match="inner dims"):
        dp.spgemm(A, A, engine="scl-array")


# ---------------------------------------------------------------------------
# autotune cache
# ---------------------------------------------------------------------------

def test_heuristic_plan_is_cached_and_reused(cache):
    A = _regime_matrix(REGIMES["mid"])
    dp.spgemm(A, A, engine="auto", cache=cache)
    key = dp.cache_key(A, A)
    hit = cache.get(key)
    assert hit is not None and hit["source"] == "heuristic"
    # a same-bucket matrix reuses the plan from a fresh cache object (disk)
    reread = dp.AutotuneCache(cache.path)
    assert reread.get(key) == hit


def test_autotune_measures_and_sticks(cache):
    A = random_sparse(24, 24, 0.05, seed=1)
    out = dp.spgemm(A, A, engine="auto", autotune=True, cache=cache)
    np.testing.assert_allclose(_dense(out),
                               _dense(sg.spgemm_scl_array(A, A)),
                               rtol=1e-4, atol=1e-4)
    hit = cache.get(dp.cache_key(A, A))
    assert hit["source"] == "autotune"
    assert hit["engine"] in dp.available_engines()
    # a later non-autotune call must keep the measured plan
    dp.spgemm(A, A, engine="auto", cache=cache)
    assert cache.get(dp.cache_key(A, A)) == hit


def test_corrupt_cache_file_starts_empty(tmp_path):
    p = tmp_path / "autotune.json"
    p.write_text("{not json")
    c = dp.AutotuneCache(str(p))
    assert len(c) == 0
    c.put("k", "esc", "heuristic")
    assert dp.AutotuneCache(str(p)).get("k") == {"engine": "esc",
                                                 "source": "heuristic"}
    # the corrupt payload was moved aside, not silently destroyed
    assert (tmp_path / "autotune.json.corrupt").read_text() == "{not json"


def test_truncated_cache_file_recovers(tmp_path):
    """A flush interrupted mid-write in older versions left a truncated
    JSON file; loading one must recover to empty and keep serving."""
    import json
    p = tmp_path / "autotune.json"
    full = json.dumps({"k": {"engine": "esc", "source": "heuristic"}})
    p.write_text(full[:len(full) // 2])
    c = dp.AutotuneCache(str(p))
    assert len(c) == 0
    c.put("k2", "spz", "heuristic")
    assert dp.AutotuneCache(str(p)).get("k2") is not None


def test_flush_is_atomic_tempfile_rename(tmp_path, monkeypatch):
    """Writes go to a tempfile and are published by rename: a reader (or
    a crash) between the write and the rename still sees the previous
    complete file, never a partial one."""
    import os
    p = tmp_path / "autotune.json"
    c = dp.AutotuneCache(str(p))
    c.put("k1", "esc", "heuristic")
    before = p.read_text()
    real_replace = os.replace
    seen = {}

    def failing_replace(srcf, dst):
        if dst == str(p):
            seen["tmp"] = srcf
            raise OSError("simulated crash before publish")
        return real_replace(srcf, dst)

    monkeypatch.setattr(os, "replace", failing_replace)
    c.put("k2", "spz", "heuristic")
    monkeypatch.undo()
    # the tempfile was used, the target file was never touched
    assert seen["tmp"] != str(p)
    assert p.read_text() == before
    assert dp.AutotuneCache(str(p)).get("k2") is None


def test_concurrent_writers_merge_not_clobber(tmp_path):
    """Two cache objects on one path (two serving processes): a put from
    one must not erase the other's entries, and a measured ("autotune")
    entry is never downgraded by a later heuristic writer."""
    p = str(tmp_path / "autotune.json")
    c1, c2 = dp.AutotuneCache(p), dp.AutotuneCache(p)
    c1.put("a", "esc", "heuristic")
    c2.put("b", "spz", "autotune")      # c2 loaded before c1's write? no:
    # c2 first touches disk here, so it merges c1's entry on flush
    reread = dp.AutotuneCache(p)
    assert reread.get("a") == {"engine": "esc", "source": "heuristic"}
    assert reread.get("b") == {"engine": "spz", "source": "autotune"}
    # c1 (stale in-memory view) re-puts "b" heuristically: the on-disk
    # autotune entry must survive the merge
    c1.put("b", "esc", "heuristic")
    assert dp.AutotuneCache(p).get("b") == {"engine": "spz",
                                            "source": "autotune"}


def test_concurrent_flushes_lose_no_entries(tmp_path):
    """The fcntl file lock closes the documented flush race: many cache
    objects on one path flushing concurrently (the multi-process serving
    pattern, here one fd-per-object across threads) must not lose a
    single entry to the read-merge-write window."""
    import threading
    p = str(tmp_path / "autotune.json")
    n_writers, n_keys = 6, 12
    barrier = threading.Barrier(n_writers)
    errors = []

    def writer(w):
        try:
            c = dp.AutotuneCache(p)
            barrier.wait()
            for i in range(n_keys):
                c.put(f"w{w}-k{i}", "esc", "heuristic")
        except Exception as e:  # pragma: no cover - surfaced via errors
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    final = dp.AutotuneCache(p)
    missing = [f"w{w}-k{i}" for w in range(n_writers)
               for i in range(n_keys) if final.get(f"w{w}-k{i}") is None]
    assert not missing, f"lost {len(missing)} entries: {missing[:5]}"


def test_cache_put_records_backend(tmp_path):
    c = dp.AutotuneCache(str(tmp_path / "autotune.json"))
    c.put("k", "spz-fused", "autotune", backend="pallas")
    assert c.get("k") == {"engine": "spz-fused", "source": "autotune",
                          "backend": "pallas"}
    reread = dp.AutotuneCache(c.path)
    assert reread.get("k")["backend"] == "pallas"


# ---------------------------------------------------------------------------
# batched execution
# ---------------------------------------------------------------------------

def _ragged_batch(seed=0, n=48):
    """Same shape, very different nnz per lane — the serving request mix."""
    densities = (0.004, 0.05, 0.015, 0.03)
    return [random_sparse(n, n, d, seed=seed + i)
            for i, d in enumerate(densities)]


@pytest.mark.parametrize("engine", ["esc", "spz", "spz-rsort", "auto"])
def test_batched_equals_per_matrix(engine, cache):
    mats = _ragged_batch()
    A = batch_csr(mats, batch_cap=len(mats) + 2)  # two padding lanes
    kw = {"R": 8, "S": 32} if engine.startswith("spz") else {}
    out = dp.spgemm_batched(A, A, engine=engine, cache=cache, **kw)
    assert isinstance(out, BatchedCSR)
    assert np.asarray(out.valid).tolist() == [True] * len(mats) + [False] * 2
    for i, m in enumerate(mats):
        want = _dense(sg.spgemm_scl_array(m, m))
        np.testing.assert_allclose(_dense(out[i]), want, rtol=1e-4,
                                   atol=1e-4)


def test_batched_maps_scalar_engines_to_esc():
    """Explicit scalar engine names fall back to the nearest batchable
    engine instead of erroring — the serving path never hard-fails on a
    heuristic that picked a scalar engine."""
    mats = _ragged_batch()
    A = batch_csr(mats)
    out = dp.spgemm_batched(A, A, engine="scl-hash")
    for i, m in enumerate(mats):
        np.testing.assert_allclose(_dense(out[i]),
                                   _dense(sg.spgemm_scl_array(m, m)),
                                   rtol=1e-4, atol=1e-4)


def test_batched_validates_shapes():
    A = batch_csr(_ragged_batch(n=16))
    B = batch_csr(_ragged_batch(n=32))
    with pytest.raises(ValueError, match="batch mismatch"):
        dp.spgemm_batched(A, B)


def test_batch_csr_roundtrip_and_caps():
    mats = _ragged_batch(n=20)
    b = batch_csr(mats, nnz_cap=4096, batch_cap=8)
    assert b.nnz_cap == 4096 and b.batch == 8 and b.n_valid == len(mats)
    for i, m in enumerate(mats):
        np.testing.assert_allclose(_dense(b[i]), _dense(m))
    with pytest.raises(ValueError, match="nnz_cap"):
        batch_csr(mats, nnz_cap=1)
    with pytest.raises(ValueError, match="batch_cap"):
        batch_csr(mats, batch_cap=1)


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @st.composite
    def regime_matrix(draw):
        """Random matrices spanning all density regimes the heuristic
        distinguishes, so 'auto' exercises every engine."""
        n = draw(st.integers(8, 48))
        density = draw(st.sampled_from([0.002, 0.01, 0.03, 0.08, 0.15]))
        seed = draw(st.integers(0, 10_000))
        pattern = draw(st.sampled_from(["uniform", "powerlaw", "banded"]))
        return random_sparse(n, n, density, seed=seed, pattern=pattern)

    @settings(max_examples=25, deadline=None)
    @given(regime_matrix(), regime_matrix())
    def test_prop_auto_equals_oracle(A, B):
        if A.n_cols != B.n_rows:
            B = random_sparse(A.n_cols, B.n_cols, 0.05, seed=0)
        cache = dp.AutotuneCache("/dev/null/unwritable.json")  # no disk IO
        want = _dense(sg.spgemm_scl_array(A, B))
        got = _dense(dp.spgemm(A, B, engine="auto", cache=cache))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 5), st.integers(0, 10_000))
    def test_prop_batched_esc_equals_per_matrix(k, seed):
        rng = np.random.default_rng(seed)
        mats = [random_sparse(24, 24, float(rng.uniform(0.01, 0.1)),
                              seed=seed + i) for i in range(k)]
        out = dp.spgemm_batched(batch_csr(mats), batch_csr(mats),
                                engine="esc")
        for i, m in enumerate(mats):
            np.testing.assert_allclose(_dense(out[i]),
                                       _dense(sg.spgemm_scl_array(m, m)),
                                       rtol=1e-3, atol=1e-3)
