"""SpGEMM engine: all five implementations agree; hypothesis properties.

The hypothesis property tests are skipped (not collection-errored) when
hypothesis is not installed, so a bare checkout still runs the
deterministic tests; CI installs the pinned dev deps and runs everything.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import spgemm, spgemm_engines as sg
from repro.core.formats import (EMPTY, csr_from_coo, csr_from_dense,
                                csr_to_numpy, random_sparse)
from repro.kernels import ref


def _dense(m):
    return np.asarray(m.to_dense(), np.float64)


@pytest.mark.parametrize("pattern", ["uniform", "powerlaw", "banded"])
@pytest.mark.parametrize("method", ["scl-hash", "esc", "spz", "spz-rsort"])
def test_methods_match_oracle(pattern, method):
    A = random_sparse(96, 96, 0.03, seed=11, pattern=pattern)
    want = _dense(sg.spgemm_scl_array(A, A))
    got = _dense(spgemm(A, A, engine=method))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_canonical_spgemm_is_dispatch_and_alias_deprecated():
    """repro.core exports the dispatch entry as THE spgemm; the old
    module-level spgemm(method=...) survives as a deprecated delegate."""
    from repro.core import dispatch
    assert spgemm is dispatch.spgemm
    A = random_sparse(32, 32, 0.05, seed=2)
    want = _dense(sg.spgemm_scl_array(A, A))
    with pytest.warns(DeprecationWarning, match="deprecated"):
        got = _dense(sg.spgemm(A, A, "esc"))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("R", [8, 16, 128])
def test_spz_chunk_widths(R):
    A = random_sparse(64, 64, 0.05, seed=5, pattern="powerlaw")
    want = _dense(sg.spgemm_scl_array(A, A))
    out, stats = sg.spgemm_spz(A, A, R=R, backend="xla")
    np.testing.assert_allclose(_dense(out), want, rtol=1e-4, atol=1e-4)
    assert stats.n_mssort > 0


def test_spz_rectangular():
    rng = np.random.default_rng(0)
    A = random_sparse(40, 70, 0.06, seed=1)
    B = random_sparse(70, 50, 0.06, seed=2)
    want = _dense(sg.spgemm_scl_array(A, B))
    out, _ = sg.spgemm_spz(A, B, R=16, backend="xla")
    np.testing.assert_allclose(_dense(out), want, rtol=1e-4, atol=1e-4)
    got_esc = _dense(sg.spgemm_esc(A, B))
    np.testing.assert_allclose(got_esc, want, rtol=1e-4, atol=1e-4)


def test_rsort_reduces_or_equals_instructions_on_skewed():
    A = random_sparse(128, 128, 0.04, seed=9, pattern="powerlaw")
    _, s0 = sg.spgemm_spz(A, A, R=16, S=16, backend="xla")
    _, s1 = sg.spgemm_spz(A, A, R=16, S=16, rsort=True, backend="xla")
    assert s1.n_mssort + s1.n_mszip <= s0.n_mssort + s0.n_mszip


def test_work_stats_match_bruteforce():
    A = random_sparse(50, 50, 0.05, seed=3)
    d = _dense(A)
    w = sg.row_work(A, A)
    nnz_per_row = (d != 0).sum(1)
    expect = [(d[i] != 0) @ nnz_per_row for i in range(50)]
    np.testing.assert_array_equal(w, expect)


# ---------------------------------------------------------------------------
# hypothesis property tests (defined only when hypothesis is installed)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @st.composite
    def sparse_pair(draw):
        n = draw(st.integers(8, 40))
        density = draw(st.floats(0.01, 0.15))
        seed = draw(st.integers(0, 10_000))
        pattern = draw(st.sampled_from(["uniform", "powerlaw", "banded"]))
        return random_sparse(n, n, density, seed=seed, pattern=pattern)

    @settings(max_examples=20, deadline=None)
    @given(sparse_pair())
    def test_prop_esc_equals_oracle(A):
        want = _dense(sg.spgemm_scl_array(A, A))
        got = _dense(sg.spgemm_esc(A, A))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(sparse_pair())
    def test_prop_spz_equals_oracle(A):
        want = _dense(sg.spgemm_scl_array(A, A))
        got = _dense(sg.spgemm_spz(A, A, R=16, backend="xla")[0])
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 12), st.integers(0, 10_000))
    def test_prop_stream_sort_invariants(S, seed):
        """Sorted-unique output, conserved mass, correct lengths."""
        rng = np.random.default_rng(seed)
        R = 32
        lens = rng.integers(0, R + 1, S).astype(np.int32)
        keys = rng.integers(0, 12, (S, R)).astype(np.int32)
        vals = rng.standard_normal((S, R)).astype(np.float32)
        k, v, ln = ref.stream_sort_ref(jnp.asarray(keys), jnp.asarray(vals),
                                       jnp.asarray(lens))
        k, v, ln = np.asarray(k), np.asarray(v), np.asarray(ln)
        for s in range(S):
            kk = k[s, :ln[s]]
            assert (np.diff(kk) > 0).all()                  # strict ascending
            assert (k[s, ln[s]:] == EMPTY).all()            # packed
            np.testing.assert_allclose(v[s, :ln[s]].sum(),
                                       vals[s, :lens[s]].sum(), rtol=1e-4,
                                       atol=1e-4)           # mass conserved
            assert set(kk) == set(keys[s, :lens[s]])        # keys preserved

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000))
    def test_prop_merge_then_remerge_idempotent(seed):
        """Merging a sorted stream with an empty one emits nothing and
        consumes nothing; merging with itself accumulates values exactly
        2x."""
        rng = np.random.default_rng(seed)
        R = 16
        n = rng.integers(1, R + 1)
        keys = np.full((1, R), EMPTY, np.int32)
        vals = np.zeros((1, R), np.float32)
        keys[0, :n] = np.sort(rng.choice(100, n, replace=False))
        vals[0, :n] = rng.standard_normal(n)
        lens = np.array([n], np.int32)
        a = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lens))
        klo, vlo, khi, vhi, ca, cb, ol = ref.stream_merge_ref(*a, *a)
        assert int(ol[0]) == n and int(ca[0]) == n and int(cb[0]) == n
        merged_v = np.concatenate([np.asarray(vlo)[0],
                                   np.asarray(vhi)[0]])[:n]
        np.testing.assert_allclose(merged_v, 2 * vals[0, :n], rtol=1e-5,
                                   atol=1e-5)


def test_formats_roundtrip():
    rng = np.random.default_rng(0)
    d = rng.standard_normal((13, 17)) * (rng.random((13, 17)) < 0.2)
    m = csr_from_dense(d.astype(np.float32))
    np.testing.assert_allclose(_dense(m), d, rtol=1e-6, atol=1e-6)
    indptr, idx, val = csr_to_numpy(m)
    m2 = csr_from_coo(np.repeat(np.arange(13), np.diff(indptr)), idx, val,
                      (13, 17))
    np.testing.assert_allclose(_dense(m2), d, rtol=1e-6, atol=1e-6)
