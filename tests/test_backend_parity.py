"""Kernel-backend registry and xla/pallas parity.

The backend layer's contract is that every registered backend is
BIT-compatible: same keys, values, lengths, and instruction counters on
the same inputs, so backend choice is purely a performance decision the
dispatch layer can autotune.  The sweeps here drive the pallas backend in
interpret mode (the CI ``backend-parity`` step runs this file with
``JAX_PLATFORMS=cpu``) against the xla oracle backend.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core import stream as kvstream
from repro.core.formats import EMPTY, random_sparse
from repro.kernels import backend as kb
from repro.kernels import ref
from repro.kernels.chunk_sort import chunk_sort_pallas


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_all_backends():
    names = set(kb.available_backends())
    assert {"xla", "pallas", "ref"} <= names
    for bk in kb.available_backends().values():
        for prim in ("chunk_sort", "stream_sort", "stream_merge",
                     "merge_partitions"):
            assert callable(getattr(bk, prim)), (bk.name, prim)


def test_pallas_backend_has_native_merge_and_fused_kernels():
    """PR 5 left merge_partitions as an XLA seam on the pallas tier; it
    is now the native bitonic-merge kernel, plus the single-kernel fused
    bucket pipeline slot (other tiers compose sort + the XLA tree)."""
    from repro.kernels import merge_tree
    pallas = kb.get_backend("pallas")
    assert pallas.merge_partitions is not merge_tree.merge_partitions
    assert pallas.fused_bucket is not None
    assert kb.get_backend("xla").fused_bucket is None
    assert kb.get_backend("ref").fused_bucket is None


def test_backend_capability_flags():
    assert kb.get_backend("xla").on_device
    assert kb.get_backend("pallas").on_device
    assert not kb.get_backend("ref").on_device
    assert not kb.get_backend("ref").measure
    assert kb.get_backend("pallas").counters_exact


def test_resolve_backend():
    assert kb.resolve_backend("xla").name == "xla"
    # auto: pallas on TPU, xla elsewhere
    want = "pallas" if kb.on_tpu() else "xla"
    assert kb.resolve_backend("auto").name == want
    # an already-resolved instance passes through
    bk = kb.get_backend("pallas")
    assert kb.resolve_backend(bk) is bk


def test_unknown_backend_raises_listing_registered():
    with pytest.raises(ValueError, match="unknown kernel backend"):
        kb.get_backend("nope")
    with pytest.raises(ValueError) as ei:
        kb.resolve_backend("typo")
    for name in kb.available_backends():
        assert name in str(ei.value)


def test_spgemm_spz_unknown_backend_raises():
    """The registry replaced the old silent fall-through to XLA: an
    unknown backend name must raise, listing the registered backends."""
    A = random_sparse(8, 8, 0.1, seed=0)
    with pytest.raises(ValueError, match="unknown kernel backend"):
        sg.spgemm_spz(A, A, backend="nope")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        dp.spgemm(A, A, engine="spz", backend="nope")


# ---------------------------------------------------------------------------
# native-Pallas chunk sort: bit-identity vs the oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("key_hi", [2, 9, 1000])
@pytest.mark.parametrize("N,R", [(1, 8), (5, 16), (12, 32)])
def test_chunk_sort_pallas_bit_identical_to_ref(N, R, key_hi):
    rng = np.random.default_rng(N * R + key_hi)
    lens = rng.integers(0, R + 1, N).astype(np.int32)
    lens[0] = 0  # always include an empty chunk
    keys = rng.integers(0, key_hi, (N, R)).astype(np.int32)
    vals = rng.standard_normal((N, R)).astype(np.float32)
    args = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lens))
    for r, p in zip(ref.stream_sort_ref(*args),
                    chunk_sort_pallas(*args, interpret=True)):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(p))


def test_chunk_sort_zero_chunks_matches_oracle():
    """N=0 (an empty chunk batch) must return empty outputs on every
    backend, not crash — part of the bit-compatibility contract."""
    keys = jnp.zeros((0, 8), jnp.int32)
    vals = jnp.zeros((0, 8), jnp.float32)
    lens = jnp.zeros((0,), jnp.int32)
    for bk in kb.available_backends().values():
        ok, ov, ol = bk.chunk_sort(keys, vals, lens)
        assert ok.shape == (0, 8) and ov.shape == (0, 8)
        assert ol.shape == (0,)


def _padded_streams(rng, S, L, key_hi):
    """(S, L) EMPTY-padded unsorted product streams with ragged plens
    (always including at least one empty stream when S > 1)."""
    plens = rng.integers(0, L + 1, S).astype(np.int32)
    if S > 1:
        plens[rng.integers(0, S)] = 0
    mask = np.arange(L)[None, :] < plens[:, None]
    keys = np.where(mask, rng.integers(0, key_hi, (S, L)), EMPTY)
    vals = np.where(mask, rng.standard_normal((S, L)), 0.0)
    return (jnp.asarray(keys.astype(np.int32)),
            jnp.asarray(vals.astype(np.float32)), jnp.asarray(plens))


def _assert_backend_parity(S, L, R, seed):
    """chunk_sort_partitions + fused_sort_merge: pallas (interpret) must
    be bit-identical to xla — keys, vals, lens AND the exact mssort/mszip
    counter values."""
    rng = np.random.default_rng(seed)
    keys, vals, plens = _padded_streams(rng, S, L, key_hi=3 * L)
    outs = {}
    for backend in ("xla", "pallas"):
        sk, sv, sl, n_mssort, sort_elems = kvstream.chunk_sort_partitions(
            keys, vals, plens, R=R, backend=backend)
        mk, mv, ml, counters = kvstream.fused_sort_merge(
            keys, vals, plens, R=R, backend=backend)
        outs[backend] = [sk, sv, sl, n_mssort, sort_elems,
                         mk, mv, ml, counters]
    for i, (x, p) in enumerate(zip(*outs.values())):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(p),
                                      err_msg=f"output {i}")


@pytest.mark.parametrize("S,L,R", [(4, 32, 8), (1, 16, 16), (6, 64, 16)])
def test_backend_parity_fixed_buckets(S, L, R):
    _assert_backend_parity(S, L, R, seed=S + L + R)


def test_backend_parity_all_empty_streams():
    S, L, R = 4, 32, 8
    keys = jnp.full((S, L), EMPTY, jnp.int32)
    vals = jnp.zeros((S, L), jnp.float32)
    plens = jnp.zeros((S,), jnp.int32)
    for backend in ("xla", "pallas"):
        mk, mv, ml, counters = kvstream.fused_sort_merge(
            keys, vals, plens, R=R, backend=backend)
        assert int(np.asarray(ml).sum()) == 0
        assert int(np.asarray(counters)[2]) == 0  # n_mszip


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 8),            # S streams
           st.sampled_from([1, 2, 4]),   # C chunks per stream
           st.sampled_from([8, 16]),     # R chunk width
           st.integers(0, 10_000))
    def test_prop_backend_parity_random_buckets(S, C, R, seed):
        """Random (S, L, R) work buckets, ragged/empty streams included:
        keys/vals/lens and mssort/mszip counters bit-equal across
        backends."""
        _assert_backend_parity(S, C * R, R, seed)


# ---------------------------------------------------------------------------
# native-Pallas merge_partitions: bit-identity vs the XLA tree and the host
# ---------------------------------------------------------------------------

def _sorted_unique_partitions(rng, N, L, key_hi, force_empty=False):
    """(N, L) EMPTY-padded ascending duplicate-free partitions — the
    contract both merge_partitions backends share."""
    keys = np.full((N, L), EMPTY, np.int32)
    vals = np.zeros((N, L), np.float32)
    lens = rng.integers(0, L + 1, N).astype(np.int32)
    if force_empty and N > 0:
        lens[rng.integers(0, N)] = 0
    for s in range(N):
        u = rng.choice(key_hi, size=min(int(lens[s]), key_hi), replace=False)
        u.sort()
        lens[s] = len(u)
        keys[s, :len(u)] = u
        vals[s, :len(u)] = rng.standard_normal(len(u))
    return jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lens)


def _assert_merge_partitions_parity(N, La, Lb, R, S, seed):
    rng = np.random.default_rng(seed)
    key_hi = 2 * (La + Lb) + 3
    ka, va, la = _sorted_unique_partitions(rng, N, La, key_hi,
                                           force_empty=True)
    kbk, vb, lb = _sorted_unique_partitions(rng, N, Lb, key_hi)
    outs = []
    for backend in ("xla", "pallas"):
        k, v, ln, cnt = kvstream.merge_partitions(
            ka, va, la, kbk, vb, lb, R=R, pair_streams=S, backend=backend)
        outs.append((k, v, ln, *cnt))
    for i, (x, p) in enumerate(zip(*outs)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(p),
                                      err_msg=f"output {i}")


@pytest.mark.parametrize("N,La,Lb,R,S", [
    (4, 16, 16, 8, 2),    # two pairs
    (1, 8, 24, 8, None),  # ragged sides, single pair
    (6, 5, 3, 4, 3),      # non-pow2 widths (kernel pads to the network)
    (3, 16, 0, 8, None),  # one side empty everywhere
])
def test_merge_partitions_pallas_bit_identical_to_xla(N, La, Lb, R, S):
    _assert_merge_partitions_parity(N, La, Lb, R, S, seed=N + La + Lb)


def test_merge_partitions_pallas_matches_host_merge_round():
    """The pallas merge kernel vs the HOST chunk-loop driver: merged
    streams and the exact n_mszip/zip_elems accounting."""
    rng = np.random.default_rng(7)
    N, La, Lb, R = 4, 24, 16, 8
    ka, va, la = _sorted_unique_partitions(rng, N, La, 60)
    kbk, vb, lb = _sorted_unique_partitions(rng, N, Lb, 60)
    ka_n, va_n = np.asarray(ka), np.asarray(va)
    kb_n, vb_n = np.asarray(kbk), np.asarray(vb)
    stats = sg.SpzStats()
    hk, hv, hl = sg.merge_round(
        (ka_n, va_n, np.asarray(la).astype(np.int64)),
        (kb_n, vb_n, np.asarray(lb).astype(np.int64)), R, "xla", stats)
    k, v, ln, cnt = kvstream.merge_partitions(ka, va, la, kbk, vb, lb,
                                              R=R, backend="pallas")
    k, v, ln = np.asarray(k), np.asarray(v), np.asarray(ln)
    np.testing.assert_array_equal(hl, ln)
    for s in range(N):
        np.testing.assert_array_equal(hk[s, :hl[s]], k[s, :ln[s]])
        np.testing.assert_array_equal(hv[s, :hl[s]], v[s, :ln[s]])
    assert int(cnt.n_mszip) == stats.n_mszip
    assert int(cnt.zip_elems) == stats.zip_elems


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 6),            # N streams
           st.integers(0, 3),            # La in chunks (0 => empty side)
           st.integers(0, 3),            # Lb in chunks
           st.sampled_from([4, 8, 16]),  # R chunk width
           st.integers(0, 10_000))
    def test_prop_merge_partitions_parity(N, Ca, Cb, R, seed):
        """Random (S, L, R) partition pairs — empty and single-chunk
        partitions included — bit-equal keys/vals/lens and exact
        n_mszip/zip_elems/chunk counters across backends."""
        S = N if seed % 2 else None  # alternate pair_streams accounting
        _assert_merge_partitions_parity(N, Ca * R, Cb * R, R, S, seed)


# ---------------------------------------------------------------------------
# the fused spz engine across backends
# ---------------------------------------------------------------------------

def _assert_spz_backends_identical(A, B, **kw):
    out_x, st_x = sg.spgemm_spz(A, B, backend="xla", driver="fused", **kw)
    out_p, st_p = sg.spgemm_spz(A, B, backend="pallas", driver="fused", **kw)
    nnz = int(np.asarray(out_x.indptr)[-1])
    np.testing.assert_array_equal(np.asarray(out_x.indptr),
                                  np.asarray(out_p.indptr))
    np.testing.assert_array_equal(np.asarray(out_x.indices)[:nnz],
                                  np.asarray(out_p.indices)[:nnz])
    np.testing.assert_array_equal(np.asarray(out_x.data)[:nnz],
                                  np.asarray(out_p.data)[:nnz])
    assert (st_x.n_mssort, st_x.sort_elems, st_x.n_mszip, st_x.zip_elems) \
        == (st_p.n_mssort, st_p.sort_elems, st_p.n_mszip, st_p.zip_elems)


def test_fused_spz_pallas_backend_bit_identical():
    A = random_sparse(48, 48, 0.05, seed=3, pattern="powerlaw")
    _assert_spz_backends_identical(A, A, R=8)


@pytest.mark.slow  # 13 interpret-mode fused runs (~minutes)
def test_fused_spz_pallas_backend_all_table3_matrices():
    """The acceptance sweep: on every table3 matrix the Pallas chunk-sort
    runs inside spgemm_spz(driver="fused") via the registry and the CSR
    output + instruction counters are bit-identical to the xla backend."""
    from benchmarks import datasets
    for name in datasets.names():
        A = datasets.build(name)
        _assert_spz_backends_identical(A, A, R=16)
