"""Per-architecture smoke tests: reduced configs, one forward + one train
step on CPU, output shapes + finiteness; prefill/decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.launch import steps as st
from repro.models import model as M
from repro.optim import adamw

# one forward + one train step per architecture: dominated by XLA compiles
# (5-20 s per arch) — slow lane only
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.num_frontend_tokens:
        batch["enc_inp"] = jax.random.normal(
            KEY, (B, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = cb.get_smoke_config(arch)
    params = M.init_params(cfg, KEY)
    batch = _batch(cfg)
    logits, aux, _ = M.forward(params, cfg, batch["tokens"],
                               enc_inp=batch.get("enc_inp"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_train_step(arch):
    cfg = cb.get_smoke_config(arch)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    state = st.init_train_state(cfg, opt_cfg, KEY)
    step = jax.jit(st.make_train_step(cfg, opt_cfg))
    batch = _batch(cfg)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])  # same batch: must improve
    assert int(state["opt"]["step"]) == 2


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = cb.get_smoke_config(arch)
    params = M.init_params(cfg, KEY)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S + 2), 0,
                              cfg.vocab_size)
    enc = None
    if cfg.num_frontend_tokens:
        enc = jax.random.normal(KEY, (B, cfg.num_frontend_tokens,
                                      cfg.d_model), jnp.float32)
    full, _, _ = M.forward(params, cfg, toks, enc_inp=enc)
    full = np.asarray(full, np.float32)
    cache = M.init_cache(cfg, B, 40, enc_len=cfg.num_frontend_tokens)
    lg, cache = M.prefill(params, cfg, toks[:, :S], cache, enc_inp=enc)
    tol = 0.15  # bf16 activations; parallel-vs-sequential scan reorderings
    assert np.abs(np.asarray(lg, np.float32) - full[:, S - 1]).max() < tol
    lg, cache = M.decode_step(params, cfg, toks[:, S:S + 1], cache,
                              jnp.int32(S))
    assert np.abs(np.asarray(lg, np.float32) - full[:, S]).max() < tol
    lg, cache = M.decode_step(params, cfg, toks[:, S + 1:S + 2], cache,
                              jnp.int32(S + 1))
    assert np.abs(np.asarray(lg, np.float32) - full[:, S + 1]).max() < tol


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_full_config_exact_numbers(arch):
    """The full (non-smoke) configs carry the assigned numbers."""
    cfg = cb.get_config(arch)
    expect = {
        "tinyllama_1_1b": (22, 2048, 32, 4, 5632, 32000),
        "phi4_mini_3_8b": (32, 3072, 24, 8, 8192, 200064),
        "qwen1_5_0_5b": (24, 1024, 16, 16, 2816, 151936),
        "granite_3_2b": (40, 2048, 32, 8, 8192, 49155),
        "llama_3_2_vision_11b": (40, 4096, 32, 8, 14336, 128256),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "deepseek_v2_236b": (60, 5120, 128, 128, 12288, 102400),
        "mamba2_780m": (48, 1536, 1, 1, 0, 50280),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect
    # group patterns cover num_layers exactly
    total = sum(len(pat) * rep for pat, rep in cfg.groups) + cfg.first_k_dense
    assert total == cfg.num_layers


def test_param_counts_plausible():
    approx = {
        "tinyllama_1_1b": 1.1e9, "phi4_mini_3_8b": 3.8e9,
        "qwen1_5_0_5b": 0.5e9, "granite_3_2b": 2.5e9,
        "llama_3_2_vision_11b": 9.8e9, "recurrentgemma_9b": 9e9,
        "arctic_480b": 482e9, "deepseek_v2_236b": 236e9,
        "mamba2_780m": 0.78e9, "whisper_small": 0.24e9,
    }
    for arch, want in approx.items():
        n = cb.get_config(arch).param_count()
        assert 0.5 * want < n < 1.7 * want, (arch, n, want)


def test_moe_zipper_equals_einsum_single_device():
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(cb.get_smoke_config("arctic_480b"),
                              capacity_factor=8.0)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model), jnp.float32)
    p = moe_mod.moe_init(KEY, cfg, jnp.float32)
    y1, _ = moe_mod.moe_block(p, x, cfg, dispatch="einsum")
    # without a mesh the zipper path falls back to einsum — same numbers
    y2, _ = moe_mod.moe_block(p, x, cfg, dispatch="zipper")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
