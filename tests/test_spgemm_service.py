"""Continuous SpGEMM service: bucketing, flush triggers (batch-full /
timeout / drain), result correctness, latency accounting, and the
autotune-cache steady state (>90% plan hit rate after warmup on mixed
synthetic traffic). All timing is driven through an injected virtual
clock, so every assertion is deterministic."""
import numpy as np
import pytest

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core.formats import random_sparse
from repro.serving import spgemm_service as svc


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


@pytest.fixture
def cache(tmp_path):
    return dp.AutotuneCache(str(tmp_path / "autotune.json"))


def _service(cache, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("flush_timeout", 1.0)
    clock = VirtualClock()
    return svc.SpGemmService(cache=cache, clock=clock, **kw), clock


def _mat(n=48, density=0.02, seed=0, pattern="uniform"):
    return random_sparse(n, n, density, seed=seed, pattern=pattern)


# ---------------------------------------------------------------------------
# bucketing + flush triggers
# ---------------------------------------------------------------------------

def test_bucket_key_pads_to_pow2():
    A = _mat(seed=1)
    key = svc.bucket_key(A, A)
    assert key[0] == key[1] == (48, 48)
    nnz = int(np.asarray(A.indptr)[-1])
    assert key[2] >= nnz and key[2] & (key[2] - 1) == 0


def test_flush_on_batch_full(cache):
    service, clock = _service(cache, max_batch=3)
    reqs = [service.submit(_mat(seed=s), _mat(seed=s)) for s in (1, 1, 1)]
    assert all(r.done for r in reqs)          # third submit filled the bucket
    assert service.pending == 0
    assert service.flush_log[-1].reason == "full"
    assert service.flush_log[-1].n_requests == 3


def test_flush_on_timeout_via_pump(cache):
    service, clock = _service(cache, max_batch=8, flush_timeout=0.5)
    r = service.submit(_mat(seed=2), _mat(seed=2))
    assert not r.done and service.pump() == 0  # too young
    clock.advance(0.6)
    assert service.pump() == 1
    assert r.done and service.flush_log[-1].reason == "timeout"
    assert r.latency == pytest.approx(0.6)


def test_mixed_shapes_land_in_separate_buckets(cache):
    service, clock = _service(cache, max_batch=2)
    a = service.submit(_mat(n=32, seed=1), _mat(n=32, seed=1))
    b = service.submit(_mat(n=48, seed=1), _mat(n=48, seed=1))
    assert a.bucket != b.bucket and service.pending == 2
    service.drain()
    assert a.done and b.done
    assert {f.reason for f in service.flush_log} == {"drain"}


def test_submit_validates_dims(cache):
    service, _ = _service(cache)
    with pytest.raises(ValueError, match="inner dims"):
        service.submit(_mat(n=32), _mat(n=48))


# ---------------------------------------------------------------------------
# correctness
# ---------------------------------------------------------------------------

def test_results_match_oracle(cache):
    service, clock = _service(cache, max_batch=4)
    mats = [_mat(seed=s, density=d, pattern=p)
            for s, (d, p) in enumerate([(0.004, "uniform"),
                                        (0.05, "uniform"),
                                        (0.02, "powerlaw"),
                                        (0.03, "banded")])]
    reqs = [service.submit(m, m) for m in mats]
    service.drain()
    for r, m in zip(reqs, mats):
        want = np.asarray(sg.spgemm_scl_array(m, m).to_dense(), np.float64)
        got = np.asarray(r.result.to_dense(), np.float64)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        assert r.engine in dp.available_engines()


# ---------------------------------------------------------------------------
# steady state
# ---------------------------------------------------------------------------

def test_plan_hit_rate_exceeds_90pct_after_warmup(cache):
    """Mixed synthetic traffic: after one warmup pass over the traffic
    classes, selections come from the autotune cache and the plan hit
    rate clears 0.9 — the acceptance bar for the serving layer."""
    service, clock = _service(cache, max_batch=4, flush_timeout=10.0)
    rng = np.random.default_rng(0)
    classes = [(32, 0.02, "uniform"), (48, 0.05, "uniform"),
               (48, 0.008, "powerlaw"), (64, 0.03, "banded")]
    mats = {c: _mat(n=c[0], density=c[1], pattern=c[2], seed=i)
            for i, c in enumerate(classes)}
    # warmup: one request per class, then drain -> every bucket planned
    for c in classes:
        service.submit(mats[c], mats[c], now=clock.advance(0.001))
    service.drain()
    snap = (len(service.completed), len(service.flush_log))
    # steady state: 60 requests over the same classes
    for _ in range(60):
        c = classes[int(rng.integers(len(classes)))]
        service.submit(mats[c], mats[c], now=clock.advance(0.001))
    service.drain()
    stats = service.stats(since_request=snap[0], since_flush=snap[1])
    assert stats["n_requests"] == 60
    assert stats["plan_hit_rate"] > 0.9, stats
    assert stats["p50_latency_s"] <= stats["p95_latency_s"]
    assert stats["req_per_s"] > 0


def test_stats_and_bucket_outcomes(cache):
    service, clock = _service(cache, max_batch=2)
    m = _mat(seed=9)
    for _ in range(4):
        service.submit(m, m, now=clock.advance(0.01))
    service.drain()
    stats = service.stats()
    assert stats["n_requests"] == 4 and stats["n_flushes"] == 2
    assert stats["n_buckets"] == 1 and stats["pending"] == 0
    outcomes = service.bucket_outcomes()
    assert len(outcomes) == 1
    (key, b), = outcomes.items()
    assert b["requests"] == 4 and b["flushes"] == 2
    assert b["plan_hits"] >= 1          # second flush reuses the cached plan
    assert sum(b["engines"].values()) == 2


def test_esc_bucket_cap_is_sticky(cache):
    """Flushes of one pad bucket must not flap the esc product capacity
    across a pow2 boundary (each flap is a fresh XLA compile): the
    service pins each bucket's cap_products to its running maximum, and
    a raised cap (always a safe upper bound) keeps results exact."""
    service, clock = _service(cache, max_batch=1, engine="esc")
    m = _mat(seed=1)
    key = svc.bucket_key(m, m)
    service.submit(m, m, now=clock.advance(0.01))
    cap = service._bucket_caps[key]
    assert cap & (cap - 1) == 0
    # simulate a heavier earlier flush: pin a larger cap, then reflush —
    # the cap must never shrink back
    service._bucket_caps[key] = cap * 4
    service.submit(m, m, now=clock.advance(0.01))
    assert service._bucket_caps[key] == cap * 4
    want = np.asarray(sg.spgemm_scl_array(m, m).to_dense(), np.float64)
    for r in service.completed:
        np.testing.assert_allclose(
            np.asarray(r.result.to_dense(), np.float64), want,
            rtol=1e-4, atol=1e-4)


def test_latencies_use_injected_clock(cache):
    service, clock = _service(cache, max_batch=2)
    m = _mat(seed=4)
    r1 = service.submit(m, m, now=0.0)
    clock.t = 5.0
    r2 = service.submit(m, m, now=5.0)  # fills the bucket -> flush at t=5
    assert r1.latency == pytest.approx(5.0)
    assert r2.latency == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# edge cases (PR 6 satellites)
# ---------------------------------------------------------------------------

def test_drain_with_empty_queue_is_a_noop(cache):
    service, clock = _service(cache)
    assert service.drain() == 0
    assert service.pump() == 0
    assert not service.flush_log and service.pending == 0
    stats = service.stats()
    assert stats["n_requests"] == 0 and stats["n_flushes"] == 0
    assert "availability" not in stats  # nothing resolved yet


def test_timeout_firing_during_in_flight_flush(cache):
    """A flush that runs long enough for another bucket's timeout to
    expire mid-flight must not lose that bucket: the next pump picks it
    up, and no request is dropped."""
    from repro.runtime import faultinject as fi
    service, clock = _service(cache, max_batch=8, flush_timeout=0.5)
    slow = service.submit(_mat(n=32, seed=1), _mat(n=32, seed=1), now=0.0)
    late = service.submit(_mat(n=48, seed=1), _mat(n=48, seed=1), now=0.4)
    clock.t = 0.5  # only the first bucket is due
    # the in-flight flush "takes" 0.6s of virtual time: the second
    # bucket's timeout expires while the first is still flushing
    spec = fi.FaultSpec(site="service.flush", kind="call",
                        action=lambda **ctx: clock.advance(0.6))
    with fi.injected(spec):
        assert service.pump() == 1
    assert slow.done and not late.done      # not flushed mid-iteration...
    assert service.pump() == 1              # ...but the next pump gets it
    assert late.done and service.pending == 0
    assert [f.reason for f in service.flush_log] == ["timeout", "timeout"]


def test_duplicate_submissions_get_distinct_ids(cache):
    """Submitting the same matrix objects repeatedly must yield unique
    request ids that each resolve independently via lookup."""
    service, clock = _service(cache, max_batch=2)
    m = _mat(seed=7)
    reqs = [service.submit(m, m, now=clock.advance(0.01)) for _ in range(4)]
    ids = [r.id for r in reqs]
    assert len(set(ids)) == 4
    service.drain()
    for r in reqs:
        assert service.lookup(r.id) is r and r.done
    want = np.asarray(sg.spgemm_scl_array(m, m).to_dense())
    for r in reqs:
        np.testing.assert_allclose(np.asarray(r.result.to_dense()), want,
                                   rtol=1e-4, atol=1e-4)


def test_hit_rate_accounting_when_flush_fails(cache):
    """A flush that falls off the planned tier must count as a plan
    miss, not a hit — availability and hit-rate accounting stay honest
    under degradation."""
    from repro.runtime import faultinject as fi
    service, clock = _service(cache, max_batch=2)
    service.policy = dp.RetryPolicy(max_attempts=2, backoff_base_s=0.0)
    m = _mat(seed=8)
    # warm flush: plan lands in the cache
    for _ in range(2):
        service.submit(m, m, now=clock.advance(0.01))
    assert service.flush_log[-1].tier == "planned"
    # poisoned flush: every batched kernel dies -> isolation serves it
    with fi.injected(fi.FaultSpec(site="kernel.batched")):
        for _ in range(2):
            service.submit(m, m, now=clock.advance(0.01))
    rec = service.flush_log[-1]
    assert rec.tier == "isolated" and not rec.plan_hit
    assert rec.attempts > 1 and rec.errors
    stats = service.stats()
    assert stats["n_requests"] == 4 and stats["availability"] == 1.0
    assert stats["n_degraded"] == 2
    # request-weighted hit rate: the isolated flush's 2 requests are
    # misses even though the bucket's plan sits in the cache
    assert stats["plan_hit_rate"] <= 0.5


def test_deadline_expiry_dead_letters_stale_requests(cache):
    service, clock = _service(cache, max_batch=8, flush_timeout=0.5)
    service.policy = dp.RetryPolicy(deadline_s=1.0)
    m = _mat(seed=9)
    r = service.submit(m, m, now=0.0)
    clock.t = 2.0  # past the per-request deadline before the flush runs
    service.drain()
    assert r.failed and r.error.stage == "deadline"
    assert r.error.kind == "DeadlineExceeded"
    assert service.lookup(r.id) is r and r in service.dead_letters
    assert service.stats()["availability"] == 0.0
    assert service.flush_log[-1].n_failed == 1
