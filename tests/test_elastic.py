"""Elastic re-meshing (`runtime/elastic.py`): the lane-partition policy
the process coordinator uses for shrink/grow, the (data, model) remesh
fallback, and the save-on-one-mesh / restore-on-another round trip.

The pure partition policy runs everywhere; the device-count shrink/grow
and resharded-restore cases need a real multi-device mesh, so they run
in an 8-host-device subprocess (slow lane, like test_distributed)."""
import os
import subprocess
import sys

import pytest

from repro.runtime import elastic

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# remesh_lanes: the coordinator's shrink/grow partition policy
# ---------------------------------------------------------------------------

def test_remesh_lanes_even_split():
    assert elastic.remesh_lanes(8, 2) == [range(0, 4), range(4, 8)]
    assert elastic.remesh_lanes(8, 4) == [range(0, 2), range(2, 4),
                                          range(4, 6), range(6, 8)]


def test_remesh_lanes_remainder_goes_to_early_workers():
    parts = elastic.remesh_lanes(8, 3)
    assert [len(p) for p in parts] == [3, 3, 2]
    # contiguous, disjoint, covering
    flat = [i for p in parts for i in p]
    assert flat == list(range(8))


def test_remesh_lanes_shrink_then_grow_is_deterministic():
    # a 4-worker pool losing one: the survivors re-cover the lane space
    assert [len(p) for p in elastic.remesh_lanes(8, 4)] == [2, 2, 2, 2]
    assert [len(p) for p in elastic.remesh_lanes(8, 3)] == [3, 3, 2]
    # the worker returns: the partition grows back to the original
    assert elastic.remesh_lanes(8, 4) == elastic.remesh_lanes(8, 4)


def test_remesh_lanes_more_workers_than_lanes_share_lane_zero():
    parts = elastic.remesh_lanes(2, 5)
    assert [len(p) for p in parts] == [1] * 5
    assert parts[0] == range(0, 1) and parts[1] == range(1, 2)
    # surplus workers fall back to lane 0 — never zero lanes
    assert parts[2] == parts[3] == parts[4] == range(0, 1)


def test_remesh_lanes_rejects_degenerate_inputs():
    with pytest.raises(ValueError):
        elastic.remesh_lanes(8, 0)
    with pytest.raises(ValueError):
        elastic.remesh_lanes(0, 2)


def test_remesh_single_device_fallback():
    """remesh on whatever devices the local run has: the fallback mesh
    keeps the (data, model) axes and covers the requested devices."""
    import jax
    n = len(jax.devices())
    mesh = elastic.remesh(n)
    assert set(mesh.axis_names) >= {"data", "model"}
    assert len(mesh.devices.reshape(-1)) >= 1


# ---------------------------------------------------------------------------
# shrink/grow on a real 8-device mesh (subprocess, slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_remesh_shrink_grow_8_devices():
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {repr(SRC)})
import jax
from repro.runtime import elastic

m8 = elastic.remesh(8)
n8 = len(m8.devices.reshape(-1))
m4 = elastic.remesh(4)   # shrink: half the pool left
n4 = len(m4.devices.reshape(-1))
m8b = elastic.remesh(8)  # grow back
assert n4 < n8, (n4, n8)
assert n4 == 4 and n8 == 8, (n4, n8)
assert m8b.axis_names == m8.axis_names
assert len(m8b.devices.reshape(-1)) == n8
print("REMESH_OK", n8, n4)
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300)
    assert "REMESH_OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_reshard_restore_round_trip_resized_mesh(tmp_path):
    """Save params once (mesh-agnostic), restore onto a 4-device mesh,
    then onto the full 8-device mesh: both restores are value-identical
    and actually spread over the requested device sets."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {repr(SRC)})
import functools
import jax, numpy as np
from repro.checkpoint import ckpt
from repro.configs import base as cb
from repro.models import model as M
from repro.runtime import elastic

cfg = cb.get_smoke_config("tinyllama_1_1b")
params = M.init_params(cfg, jax.random.PRNGKey(0))
ckpt.save({repr(str(tmp_path))}, 1, params)
shapes = jax.eval_shape(functools.partial(M.init_params, cfg),
                        jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
want = [np.asarray(x) for x in jax.tree_util.tree_leaves(params)]

for n in (4, 8):  # shrink first, then grow back
    mesh = elastic.remesh(n)
    got = elastic.reshard_restore({repr(str(tmp_path))}, shapes, mesh,
                                  fsdp=False)
    n_dev = len(got["embed"]["w"].sharding.device_set)
    assert n_dev == n, (n, n_dev)
    for a, b in zip(want, jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(a, np.asarray(b))
print("RESHARD_ROUND_TRIP_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600)
    assert "RESHARD_ROUND_TRIP_OK" in r.stdout, r.stdout + r.stderr
