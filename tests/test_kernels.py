"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.formats import EMPTY
from repro.kernels import ops, ref
from repro.kernels.stream_sort import stream_sort_pallas
from repro.kernels.stream_merge import stream_merge_pallas

RNG = np.random.default_rng(42)


def _rand_chunks(S, R, key_hi, vdtype):
    lens = RNG.integers(0, R + 1, S).astype(np.int32)
    keys = RNG.integers(0, key_hi, (S, R)).astype(np.int32)
    vals = RNG.standard_normal((S, R)).astype(vdtype)
    return keys, vals, lens


def _sorted_chunks(S, R, key_hi, vdtype):
    lens = RNG.integers(0, R + 1, S).astype(np.int32)
    keys = np.full((S, R), EMPTY, np.int32)
    vals = np.zeros((S, R), vdtype)
    for s in range(S):
        u = np.sort(RNG.choice(key_hi, size=lens[s], replace=False))
        keys[s, :lens[s]] = u
        vals[s, :lens[s]] = RNG.standard_normal(lens[s]).astype(vdtype)
    return keys, vals, lens


# R >= 128 in interpret mode costs ~3 s per case — slow lane only
@pytest.mark.parametrize("R", [8, 16, 32, 64,
                               pytest.param(128, marks=pytest.mark.slow),
                               pytest.param(256, marks=pytest.mark.slow)])
@pytest.mark.parametrize("S", [1, 3, 16])
@pytest.mark.parametrize("vdtype", [np.float32, "bfloat16"])
def test_stream_sort_matches_ref(R, S, vdtype):
    vdtype = jnp.dtype(vdtype)
    keys, vals, lens = _rand_chunks(S, R, max(2, R // 2), np.float32)
    vals = vals.astype(vdtype)
    args = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lens))
    rk, rv, rl = ref.stream_sort_ref(*args)
    pk, pv, plen = stream_sort_pallas(*args, interpret=True)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(rk))
    np.testing.assert_allclose(np.asarray(pv, np.float32),
                               np.asarray(rv, np.float32),
                               rtol=2e-2 if vdtype == jnp.bfloat16 else 1e-5,
                               atol=2e-2 if vdtype == jnp.bfloat16 else 1e-5)
    np.testing.assert_array_equal(np.asarray(plen), np.asarray(rl))


@pytest.mark.parametrize("R", [8, 16, 64,
                               pytest.param(128, marks=pytest.mark.slow)])
@pytest.mark.parametrize("S", [1, 5, 16])
def test_stream_merge_matches_ref(R, S):
    ka, va, la = _sorted_chunks(S, R, 4 * R, np.float32)
    kb, vb, lb = _sorted_chunks(S, R, 4 * R, np.float32)
    args = tuple(jnp.asarray(x) for x in (ka, va, la, kb, vb, lb))
    rres = ref.stream_merge_ref(*args)
    pres = stream_merge_pallas(*args, interpret=True)
    for i, (r, p) in enumerate(zip(rres, pres)):
        r, p = np.asarray(r), np.asarray(p)
        if r.dtype.kind == "f":
            np.testing.assert_allclose(p, r, rtol=1e-5, atol=1e-5,
                                       err_msg=f"output {i}")
        else:
            np.testing.assert_array_equal(p, r, err_msg=f"output {i}")


def test_stream_sort_empty_streams():
    keys = np.full((4, 16), EMPTY, np.int32)
    vals = np.zeros((4, 16), np.float32)
    lens = np.zeros(4, np.int32)
    k, v, l = ops.stream_sort(jnp.asarray(keys), jnp.asarray(vals),
                              jnp.asarray(lens), backend="pallas")
    assert int(np.asarray(l).sum()) == 0
    assert (np.asarray(k) == EMPTY).all()


def test_stream_merge_one_side_empty():
    ka, va, la = _sorted_chunks(3, 16, 64, np.float32)
    kb = np.full((3, 16), EMPTY, np.int32)
    vb = np.zeros((3, 16), np.float32)
    lb = np.zeros(3, np.int32)
    res = ops.stream_merge(*(jnp.asarray(x)
                             for x in (ka, va, la, kb, vb, lb)),
                           backend="pallas")
    _, _, _, _, ca, cb, ol = res
    # unmergeable: nothing advances, nothing is emitted
    assert int(np.asarray(ca).sum()) == 0
    assert int(np.asarray(cb).sum()) == 0
    assert int(np.asarray(ol).sum()) == 0


def test_merge_conservation_and_counts():
    """Value mass of consumed tuples == value mass of emitted tuples."""
    ka, va, la = _sorted_chunks(8, 32, 100, np.float32)
    kb, vb, lb = _sorted_chunks(8, 32, 100, np.float32)
    klo, vlo, khi, vhi, ca, cb, ol = (
        np.asarray(t) for t in ops.stream_merge(
            *(jnp.asarray(x) for x in (ka, va, la, kb, vb, lb)),
            backend="pallas"))
    for s in range(8):
        emitted = np.concatenate([vlo[s], vhi[s]])[:ol[s]].sum()
        # consumed = keys <= cutoff on each side
        consumed = va[s, :ca[s]].sum() + vb[s, :cb[s]].sum()
        np.testing.assert_allclose(emitted, consumed, rtol=1e-4, atol=1e-4)


def test_sort_tokens_by_key_matches_argsort():
    keys = jnp.asarray(RNG.integers(0, 7, 128).astype(np.int32))
    sk, perm = ops.sort_tokens_by_key(keys, backend="pallas")
    assert (np.diff(np.asarray(sk)) >= 0).all()
    np.testing.assert_array_equal(np.asarray(keys)[np.asarray(perm)],
                                  np.asarray(sk))
    # stability: equal keys keep slot order
    p = np.asarray(perm)
    k = np.asarray(keys)
    for e in range(7):
        np.testing.assert_array_equal(np.sort(p[k[p] == e]), p[k[p] == e])


def test_flash_attention_ref_consistency():
    """mha_ref (oracle) vs blocked_attention on random GQA shapes."""
    import jax
    from repro.kernels.ref import mha_ref
    from repro.models.attention import blocked_attention
    key = jax.random.PRNGKey(3)
    for (B, Sq, H, KVH, hd, win) in [(2, 64, 4, 2, 16, 0), (1, 128, 8, 1, 8, 32),
                                     (2, 96, 4, 4, 32, 0)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, Sq, H, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, Sq, KVH, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, Sq, KVH, hd), jnp.float32)
        ref_o = mha_ref(q, k, v, causal=True, window=win)
        for skip in (False, True):
            out = blocked_attention(q, k, v, causal=True, window=win,
                                    q_block=32, kv_block=16, block_skip=skip)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o),
                                       rtol=2e-4, atol=2e-4)
