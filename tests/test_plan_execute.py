"""Plan/execute dispatch split: plans are inspectable, hashable, reusable,
and execution round-trips bit-identical to the one-shot spgemm() path for
every registered engine (single and batched)."""
import numpy as np
import pytest

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core.formats import batch_csr, random_sparse


@pytest.fixture
def cache(tmp_path):
    return dp.AutotuneCache(str(tmp_path / "autotune.json"))


def _bit_equal(a, b):
    nnz = int(np.asarray(a.indptr)[-1])
    assert np.array_equal(np.asarray(a.indptr), np.asarray(b.indptr))
    assert np.array_equal(np.asarray(a.indices)[:nnz],
                          np.asarray(b.indices)[:nnz])
    assert np.array_equal(np.asarray(a.data)[:nnz], np.asarray(b.data)[:nnz])


# ---------------------------------------------------------------------------
# single-pair plans
# ---------------------------------------------------------------------------

def test_plan_execute_bit_identical_all_engines():
    """execute(plan(...)) == the engine called directly, bit for bit."""
    A = random_sparse(64, 64, 0.04, seed=7, pattern="powerlaw")
    for name, spec in dp.available_engines().items():
        direct = spec.fn(A, A)
        direct = direct[0] if spec.returns_stats else direct
        p = dp.plan(A, A, name)
        out = dp.execute(p, A, A)
        assert p.engine == name and p.source == "explicit"
        _bit_equal(direct, out)


def test_plan_is_hashable_and_inspectable(cache):
    A = random_sparse(64, 64, 0.05, seed=0)
    p = dp.plan(A, A, "auto", cache=cache)
    assert isinstance(hash(p), int)
    assert p.engine in dp.available_engines()
    assert p.source == "heuristic" and p.rule is not None
    assert p.cache_key == dp.cache_key(A, A)
    # the jit identity: engine + operand structure + static capacities
    assert p.jit_key[0] == p.engine
    assert p.a_shape in p.jit_key and p.b_shape in p.jit_key
    # an explicit plan for the same engine lands on the same computation
    assert dp.plan(A, A, p.engine).jit_key == p.jit_key
    # second plan on the same shape bucket comes from the cache
    p2 = dp.plan(A, A, "auto", cache=cache)
    assert p2.source == "cache" and p2.engine == p.engine


def test_plan_reusable_across_matching_requests(cache):
    """One plan, many executions — the serving steady state."""
    A = random_sparse(48, 48, 0.05, seed=1)
    p = dp.plan(A, A, "auto", cache=cache)
    want = np.asarray(sg.spgemm_scl_array(A, A).to_dense())
    for seed in (2, 3):
        M = random_sparse(48, 48, 0.05, seed=seed)
        out = dp.execute(p, M, M)
        np.testing.assert_allclose(
            np.asarray(out.to_dense()),
            np.asarray(sg.spgemm_scl_array(M, M).to_dense()),
            rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dp.execute(p, A, A).to_dense()),
                               want, rtol=1e-4, atol=1e-4)


def test_execute_rejects_structure_mismatch(cache):
    A = random_sparse(32, 32, 0.05, seed=0)
    C = random_sparse(16, 16, 0.05, seed=0)
    p = dp.plan(A, A, "esc")
    with pytest.raises(ValueError, match="mismatch"):
        dp.execute(p, C, C)


def test_plan_resolves_kwargs_at_plan_time(cache):
    """auto drops kwargs the selected engine can't take; explicit engines
    stay strict (the TypeError fires at execute)."""
    A = random_sparse(64, 64, 0.05, seed=3)  # dense regime -> esc
    p = dp.plan(A, A, "auto", cache=cache, R=16, backend="xla")
    if p.engine == "esc":
        assert "R" not in p.kwargs_dict
    out = dp.execute(p, A, A)
    np.testing.assert_allclose(np.asarray(out.to_dense()),
                               np.asarray(sg.spgemm_scl_array(A, A).to_dense()),
                               rtol=1e-4, atol=1e-4)
    strict = dp.plan(A, A, "esc", R=16)
    assert strict.kwargs_dict == {"R": 16}
    with pytest.raises(TypeError):
        dp.execute(strict, A, A)


def test_plan_memo_on_operand_identity(tmp_path, monkeypatch):
    """Repeat plans on the same matrix objects skip selection entirely
    (memo hit returns the identical plan object)."""
    dp.clear_feature_cache()
    monkeypatch.setattr(dp, "_default_cache",
                        dp.AutotuneCache(str(tmp_path / "private.json")))
    A = random_sparse(48, 48, 0.03, seed=5)
    before = dp._plan_memo.hits
    p1 = dp.plan(A, A, "auto")
    p2 = dp.plan(A, A, "auto")
    assert p2 is p1
    assert dp._plan_memo.hits == before + 1
    dp.clear_feature_cache()


def test_plan_memo_invalidated_by_autotune(tmp_path, monkeypatch):
    """An autotune upgrade must not be shadowed by a stale memoized plan."""
    dp.clear_feature_cache()
    c = dp.AutotuneCache(str(tmp_path / "private.json"))
    monkeypatch.setattr(dp, "_default_cache", c)
    A = random_sparse(24, 24, 0.05, seed=1)
    p1 = dp.plan(A, A, "auto")
    tuned = dp.plan(A, A, "auto", autotune=True)
    assert tuned.source == "autotune"
    p2 = dp.plan(A, A, "auto")
    assert p2.source == "cache" and p2.engine == tuned.engine
    assert p1 is not p2
    dp.clear_feature_cache()


# ---------------------------------------------------------------------------
# kernel backend as a planned dimension
# ---------------------------------------------------------------------------

def test_plan_resolves_backend_into_kwargs_and_jit_key(cache):
    """Backend-aware engines get the resolved backend folded into the
    plan's kwargs; the jit_key separates compilations per backend."""
    A = random_sparse(48, 48, 0.05, seed=2)
    px = dp.plan(A, A, "spz-fused", backend="xla", R=8)
    pp = dp.plan(A, A, "spz-fused", backend="pallas", R=8)
    assert px.backend == "xla" and pp.backend == "pallas"
    assert px.kwargs_dict["backend"] == "xla"
    assert pp.kwargs_dict["backend"] == "pallas"
    assert px.jit_key != pp.jit_key
    # the two plans execute to bit-identical outputs (backends are
    # bit-compatible by contract)
    _bit_equal(dp.execute(px, A, A), dp.execute(pp, A, A))
    # "auto" resolves to a concrete registered backend at plan time
    pa = dp.plan(A, A, "spz-fused", R=8)
    from repro.kernels import backend as kb
    assert pa.backend == kb.resolve_backend("auto").name


def test_plan_backend_for_non_aware_engine(cache):
    """esc takes no kernel backend: explicit pins are planning errors,
    auto selection just drops the irrelevant dimension."""
    A = random_sparse(64, 64, 0.05, seed=3)  # dense regime -> esc
    with pytest.raises(ValueError, match="does not take a kernel backend"):
        dp.plan(A, A, "esc", backend="xla")
    p = dp.plan(A, A, "auto", backend="xla", cache=cache)
    if p.engine == "esc":
        assert p.backend is None and "backend" not in p.kwargs_dict


def test_two_backends_autotune_independently(tmp_path):
    """The acceptance contract: the same shape bucket autotunes one plan
    per pinned backend — distinct cache keys, distinct sticky entries."""
    cache = dp.AutotuneCache(str(tmp_path / "autotune.json"))
    A = random_sparse(16, 16, 0.08, seed=1)
    px = dp.plan(A, A, "auto", backend="xla", autotune=True, cache=cache)
    pp = dp.plan(A, A, "auto", backend="pallas", autotune=True, cache=cache)
    assert px.source == pp.source == "autotune"
    assert px.cache_key != pp.cache_key
    assert px.cache_key.endswith("|bk=xla")
    assert pp.cache_key.endswith("|bk=pallas")
    ex = cache.get(px.cache_key)
    ep = cache.get(pp.cache_key)
    assert ex is not None and ep is not None and ex["source"] == "autotune"
    # a backend-aware winner records its backend; later cached plans for
    # the pinned-pallas bucket keep routing to pallas kernels
    if dp.get_engine(pp.engine).backend_aware:
        assert pp.backend == "pallas" and ep["backend"] == "pallas"
    p2 = dp.plan(A, A, "auto", backend="pallas", cache=cache)
    assert p2.source == "cache" and p2.engine == pp.engine
    assert p2.backend == pp.backend


def test_autotune_with_auto_backend_sweeps_backends(tmp_path):
    """With backend="auto" the backend joins the autotune search space:
    backend-aware engines are measured once per measurable backend."""
    cache = dp.AutotuneCache(str(tmp_path / "autotune.json"))
    A = random_sparse(12, 12, 0.1, seed=4)
    measured = []
    real = dp._measure

    def spy(spec, a, b, repeat=1, backend=None):
        measured.append((spec.name, backend))
        return real(spec, a, b, repeat, backend)

    try:
        dp._measure = spy
        p = dp.plan(A, A, "auto", autotune=True, cache=cache)
    finally:
        dp._measure = real
    assert p.source == "autotune"
    spz_backends = {bk for name, bk in measured if name == "spz"}
    from repro.kernels import backend as kb
    # off-TPU the interpret-mode pallas tier is excluded from the sweep
    # (needs_tpu_for_perf): measuring it could only lose, slowly
    want = {bk.name for bk in kb.measurable_backends()}
    assert spz_backends == want
    if not kb.on_tpu():
        assert "pallas" not in spz_backends
    assert ("esc", None) in measured


def test_cached_backend_is_not_trusted_blindly(tmp_path):
    """A shared cache entry naming an unknown backend (version skew) or
    a TPU-only one replayed off-TPU must fall back to "auto", never
    raise or route execution through a degraded tier."""
    cache = dp.AutotuneCache(str(tmp_path / "autotune.json"))
    A = random_sparse(24, 24, 0.05, seed=6)
    key = dp.cache_key(A, A)
    from repro.kernels import backend as kb
    for bad in ("no-such-backend", "pallas" if not kb.on_tpu() else "xla"):
        cache.put(key, "spz-fused", "autotune", backend=bad)
        p = dp.plan(A, A, "auto", cache=cache)
        assert p.source == "cache" and p.engine == "spz-fused"
        if bad == "no-such-backend" or not kb.on_tpu():
            assert p.backend == kb.resolve_backend("auto").name
        dp.execute(p, A, A)  # and the plan actually runs


# ---------------------------------------------------------------------------
# batched plans
# ---------------------------------------------------------------------------

def _ragged_batch(seed=0, n=48):
    densities = (0.004, 0.05, 0.015, 0.03)
    return [random_sparse(n, n, d, seed=seed + i)
            for i, d in enumerate(densities)]


@pytest.mark.parametrize("engine", ["esc", "spz", "auto"])
def test_plan_execute_batched_bit_identical(engine, cache):
    mats = _ragged_batch()
    A = batch_csr(mats, batch_cap=6)
    kw = {"R": 8, "S": 32} if engine.startswith("spz") else {}
    want = dp.spgemm_batched(A, A, engine=engine, cache=cache, **kw)
    p = dp.plan_batched(A, A, engine, cache=cache, **kw)
    got = dp.execute_batched(p, A, A)
    assert p.batched and p.batch == A.batch
    for name in ("indptr", "indices", "data", "valid"):
        assert np.array_equal(np.asarray(getattr(want, name)),
                              np.asarray(getattr(got, name))), name


def test_batched_plan_resolves_static_capacity(cache):
    """esc batched plans pin the shared pow2 product capacity at plan
    time, so the plan's jit_key fully determines the compilation."""
    mats = _ragged_batch()
    A = batch_csr(mats)
    p = dp.plan_batched(A, A, "esc", cache=cache)
    cap = p.kwargs_dict["cap_products"]
    assert cap & (cap - 1) == 0  # power of two
    works = max(int(sg.row_work(m, m).sum()) for m in mats)
    assert cap >= works
    assert p.jit_key == dp.plan_batched(A, A, "esc", cache=cache).jit_key


def test_batched_auto_feeds_autotune_cache(cache):
    """Batched auto selection consults and persists the same autotune
    cache as the single-matrix path (the serving steady state)."""
    mats = _ragged_batch()
    A = batch_csr(mats)
    p1 = dp.plan_batched(A, A, "auto", cache=cache)
    assert p1.source == "heuristic"
    assert cache.get(p1.cache_key) is not None
    p2 = dp.plan_batched(A, A, "auto", cache=cache)
    assert p2.source == "cache" and p2.engine == p1.engine


def test_batched_plan_resolves_backend(cache):
    """The batched spz drivers are backend-aware: the plan pins the
    resolved backend and the two backends execute bit-identically."""
    mats = _ragged_batch()
    A = batch_csr(mats)
    px = dp.plan_batched(A, A, "spz-fused", backend="xla", R=8, S=32,
                         cache=cache)
    pp = dp.plan_batched(A, A, "spz-fused", backend="pallas", R=8, S=32,
                         cache=cache)
    assert px.backend == "xla" and pp.backend == "pallas"
    assert px.jit_key != pp.jit_key
    ox = dp.execute_batched(px, A, A)
    op = dp.execute_batched(pp, A, A)
    for name in ("indptr", "indices", "data", "valid"):
        assert np.array_equal(np.asarray(getattr(ox, name)),
                              np.asarray(getattr(op, name))), name


def test_execute_batched_rejects_wrong_plan_kind(cache):
    A = random_sparse(32, 32, 0.05, seed=0)
    b = batch_csr(_ragged_batch())
    single = dp.plan(A, A, "esc")
    batched = dp.plan_batched(b, b, "esc", cache=cache)
    with pytest.raises(ValueError, match="batched"):
        dp.execute_batched(single, b, b)
    with pytest.raises(ValueError, match="batched"):
        dp.execute(batched, A, A)
