"""Async pipelined serving + compile-ahead plan warming (PR 9).

Covers the PlanWarmer's prediction bookkeeping (pure, clock-free), the
warm -> first-flush jit handoff (a prewarmed bucket's first real flush
must land on the pre-compiled computation), concurrent executor flushes
(bucket state must not interleave), drain under in-flight async work
(every id resolves), and chaos: a worker SIGKILLed *mid-warm* must cost
at most the warm — availability stays 1.0.  The multiprocess test
spawns real workers and real SIGKILLs, same as ``test_multiproc``."""
import threading

import numpy as np
import pytest

from repro.core import dispatch as dp
from repro.core.formats import random_sparse
from repro.runtime import faultinject as fi
from repro.serving import spgemm_service as svc
from repro.serving.plan_warmer import PlanWarmer, neighbor_buckets


@pytest.fixture
def cache(tmp_path):
    return dp.AutotuneCache(str(tmp_path / "autotune.json"))


@pytest.fixture(autouse=True)
def _fresh_warm_stats():
    dp.reset_warm_stats()
    yield
    dp.reset_warm_stats()


def _mat(n=48, density=0.02, seed=0, pattern="uniform"):
    return random_sparse(n, n, density, seed=seed, pattern=pattern)


def _dense(csr):
    return np.asarray(csr.to_dense(), np.float64)


# ---------------------------------------------------------------------------
# PlanWarmer prediction (pure bookkeeping, no execution)
# ---------------------------------------------------------------------------

def test_warmer_configured_buckets_predicted_first():
    A, B = _mat(seed=1), _mat(n=32, seed=2)
    w = PlanWarmer(configured=[(A, A)], neighbors=False)
    for _ in range(3):
        w.observe(svc.bucket_key(B, B))
    pred = w.predict()
    assert pred[0] == svc.bucket_key(A, A)       # configured outranks observed
    assert svc.bucket_key(B, B) in pred


def test_warmer_frequency_ranking_and_min_count():
    w = PlanWarmer(neighbors=False, min_count=2)
    hot, cold = ("h",), ("c",)
    for _ in range(5):
        w.observe(hot)
    w.observe(cold)
    assert w.predict() == [hot]                  # cold below min_count
    w.observe(cold)
    assert w.predict() == [hot, cold]


def test_warmer_due_excludes_warmed_pending_failed():
    w = PlanWarmer(configured=[("a",), ("b",), ("c",)], neighbors=False)
    w.mark_pending(("a",))
    w.mark_warmed(("b",))
    w.mark_failed(("c",), "boom")
    assert w.due() == []
    w.mark_warmed(("a",))
    assert w.is_warmed(("a",)) and w.stats()["failed"] == 1


def test_warmer_budget_caps_due():
    w = PlanWarmer(configured=[(i,) for i in range(8)], neighbors=False,
                   max_warms=3)
    assert len(w.due()) == 3


def test_neighbor_buckets_guard_pow2_boundaries():
    b = ((48, 48), (48, 48), 64, 64)
    nbs = neighbor_buckets(b)
    assert ((48, 48), (48, 48), 128, 128) in nbs
    assert ((48, 48), (48, 48), 32, 32) in nbs
    # capacity already covers the full operand: no reachable up-neighbor
    full = ((4, 4), (4, 4), 16, 16)
    assert all(nb[2] <= 16 for nb in neighbor_buckets(full))


def test_warmer_keeps_heaviest_sample():
    w = PlanWarmer(neighbors=False)
    light, heavy = _mat(density=0.01, seed=1), _mat(density=0.05, seed=2)
    b = ("bucket",)
    w.observe(b, heavy, heavy)
    # a later, lighter pair must not evict the heavier retained sample —
    # the heavy pair's capacities upper-bound the bucket's traffic best
    w.observe(b, light, light)
    assert w.sample(b) == (heavy, heavy)


# ---------------------------------------------------------------------------
# warming compiles predicted buckets before the first submit
# ---------------------------------------------------------------------------

def test_prewarm_gives_plan_memo_hit_on_first_request(cache):
    A = _mat(seed=1)
    warmer = PlanWarmer(configured=[(A, A)], neighbors=False)
    service = svc.SpGemmService(cache=cache, max_batch=4, flush_timeout=1e9,
                                warmer=warmer)
    assert service.prewarm() == 1
    assert service.warm_log[-1]["ok"]
    assert warmer.is_warmed(svc.bucket_key(A, A))
    assert dp.warm_stats()["warmed"] >= 1
    # the *first* flush of real traffic lands on the pre-compiled jit
    reqs = [service.submit(_mat(seed=s), _mat(seed=s)) for s in (1, 2, 3, 4)]
    assert all(r.done and not r.failed for r in reqs)
    f = service.flush_log[-1]
    assert f.warm_hit and f.tier == "planned"
    assert dp.warm_stats()["hits"] >= 1
    assert service.stats()["warm_hit_rate"] == 1.0
    ref = dp.spgemm(reqs[0].A, reqs[0].B, engine="scl-array")
    np.testing.assert_allclose(_dense(reqs[0].result), _dense(ref),
                               rtol=1e-5, atol=1e-6)


def test_unwarmed_bucket_counts_as_warm_miss(cache):
    service = svc.SpGemmService(cache=cache, max_batch=2, flush_timeout=1e9)
    reqs = [service.submit(_mat(seed=s), _mat(seed=s)) for s in (1, 2)]
    assert all(r.done for r in reqs)
    assert not service.flush_log[-1].warm_hit
    assert service.stats()["warm_hit_rate"] == 0.0


def test_pump_dispatches_warm_work_from_admission_stream(cache):
    warmer = PlanWarmer(neighbors=False)
    service = svc.SpGemmService(cache=cache, max_batch=8, flush_timeout=1e9,
                                async_flushes=1, warmer=warmer)
    try:
        service.submit(_mat(seed=1), _mat(seed=1))
        service.pump()                    # observes the bucket -> warm job
        service.prewarm(buckets=[], block=True)   # barrier on in-flight warms
        assert warmer.is_warmed(svc.bucket_key(_mat(seed=1), _mat(seed=1)))
    finally:
        service.close()


# ---------------------------------------------------------------------------
# concurrent executor flushes
# ---------------------------------------------------------------------------

def test_concurrent_flushes_do_not_interleave_bucket_state(cache):
    """Two buckets flushing at the same time (a barrier inside the flush
    fault site proves the overlap) must each land their own results,
    provenance, and ids — no cross-bucket interleaving."""
    barrier = threading.Barrier(2, timeout=60.0)
    spec = fi.FaultSpec(site="service.flush", kind="call", max_fires=2,
                        action=lambda **ctx: barrier.wait())
    service = svc.SpGemmService(cache=cache, max_batch=2, flush_timeout=1e9,
                                async_flushes=2)
    try:
        with fi.injected(spec):
            ra = [service.submit(_mat(n=32, seed=s), _mat(n=32, seed=s))
                  for s in (1, 2)]
            rb = [service.submit(_mat(n=48, seed=s), _mat(n=48, seed=s))
                  for s in (1, 2)]
            service.drain()
        assert barrier.n_waiting == 0            # both ladders met inside
        assert all(r.done and not r.failed for r in ra + rb)
        assert service.pending == 0 and not service.dead_letters
        by_bucket = {f.bucket: f for f in service.flush_log}
        assert len(by_bucket) == 2
        assert all(f.n_requests == 2 and f.tier == "planned"
                   for f in by_bucket.values())
        for r in ra + rb:
            ref = dp.spgemm(r.A, r.B, engine="scl-array")
            np.testing.assert_allclose(_dense(r.result), _dense(ref),
                                       rtol=1e-5, atol=1e-6)
    finally:
        service.close()


def test_drain_under_inflight_async_flushes_resolves_every_id(cache):
    """drain() called while executor flushes are still running must block
    for them and resolve every submitted id exactly once."""
    spec = fi.FaultSpec(site="service.flush", kind="hang", delay_s=0.3,
                        max_fires=None)
    service = svc.SpGemmService(cache=cache, max_batch=2, flush_timeout=1e9,
                                async_flushes=2)
    try:
        with fi.injected(spec):
            reqs = [service.submit(_mat(n=n, seed=s), _mat(n=n, seed=s))
                    for n in (32, 48, 64) for s in (1, 2)]
            service.drain()
        assert service.pending == 0
        assert all(r.done for r in reqs)
        assert len(service.completed) + len(service.dead_letters) == len(reqs)
        assert not service.dead_letters
        assert {r.id for r in service.completed} == {r.id for r in reqs}
    finally:
        service.close()


def test_async_admission_does_not_block_on_flush(cache):
    """With async flushes, submit() returns while a slow flush is still
    in the executor — the admission path must stay non-blocking."""
    release = threading.Event()
    spec = fi.FaultSpec(site="service.flush", kind="call", max_fires=1,
                        action=lambda **ctx: release.wait(timeout=60.0))
    service = svc.SpGemmService(cache=cache, max_batch=2, flush_timeout=1e9,
                                async_flushes=1)
    try:
        with fi.injected(spec):
            held = [service.submit(_mat(n=32, seed=s), _mat(n=32, seed=s))
                    for s in (1, 2)]       # full bucket -> flush in executor
            assert not any(r.done for r in held)   # still held at the gate
            fresh = service.submit(_mat(n=48, seed=3), _mat(n=48, seed=3))
            assert fresh.id > held[-1].id          # admission kept moving
            release.set()
            service.drain()
        assert all(r.done and not r.failed for r in held + [fresh])
    finally:
        service.close()


# ---------------------------------------------------------------------------
# chaos: SIGKILL mid-warm in a real worker pool
# ---------------------------------------------------------------------------

def test_worker_sigkill_mid_warm_keeps_availability(tmp_path):
    """A worker SIGKILLed inside the warm task (the ``service.warm``
    fault site) must cost at most the warm itself: the pool recovers,
    traffic runs (cold), and every request resolves — availability 1.0."""
    from repro.runtime import coordinator as coord
    cache_path = str(tmp_path / "autotune.json")
    kill = fi.FaultSpec(site="service.warm", kind="kill_process", max_fires=1)
    A = _mat(seed=1)
    with coord.ProcessCoordinator(
            2, cache_path=cache_path, fault_specs=[kill],
            max_task_retries=1) as pool:
        warmer = PlanWarmer(configured=[(A, A)], neighbors=False)
        service = svc.SpGemmService(
            cache=dp.AutotuneCache(cache_path), max_batch=4,
            flush_timeout=1e9, coordinator=pool, warmer=warmer,
            policy=dp.RetryPolicy(max_attempts=5, backoff_base_s=0.0))
        service.prewarm()                 # the warm dies with its worker(s)
        assert any(e["event"] == "worker_lost" for e in pool.events)
        reqs = [service.submit(_mat(seed=s), _mat(seed=s))
                for s in (1, 2, 3, 4)]
        service.drain()
        assert all(r.done for r in reqs)
        st = service.stats()
        assert st["availability"] == 1.0 and not service.dead_letters
        for r in reqs:
            ref = dp.spgemm(r.A, r.B, engine="scl-array")
            np.testing.assert_allclose(_dense(r.result), _dense(ref),
                                       rtol=1e-5, atol=1e-6)
