"""Substrate: optimizer, data pipeline, checkpointing, fault tolerance."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import PrefetchLoader, TokenDataset
from repro.optim import adamw
from repro.runtime.fault import FaultConfig, Preempted, run_resilient


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            decay_steps=200)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw.init_state(cfg, params)
    grad_fn = jax.grad(lambda p: jnp.sum((p["w"] - 1.0) ** 2))
    for _ in range(150):
        params, opt, _ = adamw.apply_updates(cfg, params, opt, grad_fn(params))
    np.testing.assert_allclose(np.asarray(params["w"]), [1.0, 1.0], atol=1e-2)


def test_adamw_bf16_state_roundtrip():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    opt = adamw.init_state(cfg, params)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full((4, 4), 0.1)}
    p2, opt2, m = adamw.apply_updates(cfg, params, opt, g)
    assert opt2["v"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(float(m["grad_norm"]))


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(adamw.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_no_weight_decay_on_norms():
    cfg = adamw.AdamWConfig(lr=1.0, weight_decay=1.0, warmup_steps=1)
    params = {"norm": {"scale": jnp.ones(3)}, "w1": {"w": jnp.ones(3)}}
    opt = adamw.init_state(cfg, params)
    zero_g = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _, _ = adamw.apply_updates(cfg, params, opt, zero_g)
    np.testing.assert_allclose(np.asarray(p2["norm"]["scale"]), 1.0)
    assert float(p2["w1"]["w"][0]) < 1.0  # decayed


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_dataset_deterministic_and_sharded():
    ds0 = TokenDataset(1000, 16, 8, seed=7, n_shards=2, shard_id=0)
    ds1 = TokenDataset(1000, 16, 8, seed=7, n_shards=2, shard_id=1)
    a, b = ds0.batch_at(3), ds0.batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(ds0.batch_at(3)["tokens"],
                              ds1.batch_at(3)["tokens"])
    assert a["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_prefetch_loader_order_and_resume():
    ds = TokenDataset(100, 8, 4, seed=1)
    loader = PrefetchLoader(ds).start(step=5)
    b = next(loader)
    assert b["_step"] == 5
    np.testing.assert_array_equal(b["tokens"], ds.batch_at(5)["tokens"])
    loader.stop()


def test_straggler_backup_fetch():
    ds = TokenDataset(100, 8, 4, seed=1)
    calls = {"n": 0}

    def slow_fetch(step):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(1.0)  # primary straggles past the deadline
        return ds.batch_at(step)

    loader = PrefetchLoader(ds, deadline_s=0.1, fetch_fn=slow_fetch).start()
    b = next(loader)
    loader.stop()
    assert loader.backup_fetches >= 1
    np.testing.assert_array_equal(b["tokens"], ds.batch_at(0)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip_and_keep(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert sorted(ckpt.all_steps(str(tmp_path))) == [3, 4]
    out = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    assert out["b"]["c"].dtype == jnp.bfloat16


def test_ckpt_async_save(tmp_path):
    tree = {"a": jnp.zeros(10)}
    t = ckpt.save(str(tmp_path), 7, tree, blocking=False)
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 7


def test_ckpt_torn_write_invisible(tmp_path):
    # a .tmp directory must never be listed as a checkpoint
    os.makedirs(tmp_path / ".tmp_step_9")
    assert ckpt.latest_step(str(tmp_path)) is None


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def _toy_loop(tmp_path, fail_at=None, max_restarts=3):
    state = {"x": jnp.zeros(())}
    fired = {"done": False}

    def train_step(state, batch):
        return {"x": state["x"] + 1}, {"loss": 1.0 / (float(state["x"]) + 1)}

    def save_fn(step, state):
        return ckpt.save(str(tmp_path), step, state, blocking=True)

    def restore_fn():
        s = ckpt.latest_step(str(tmp_path))
        if s is None:
            return None
        return s, ckpt.restore(str(tmp_path), {"x": jnp.zeros(())}, step=s)

    def preempt(step):
        if fail_at is not None and step == fail_at and not fired["done"]:
            fired["done"] = True
            raise Preempted(f"simulated preemption at {step}")

    fcfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                       max_restarts=max_restarts)
    return run_resilient(train_step, state,
                         lambda step: {"tokens": np.zeros(1)},
                         fcfg, num_steps=10,
                         save_fn=save_fn, restore_fn=restore_fn,
                         preempt_hook=preempt)


def test_resilient_loop_completes(tmp_path):
    state, hist = _toy_loop(tmp_path)
    assert float(state["x"]) == 10
    assert hist["restarts"] == 0


def test_resilient_loop_resumes_after_preemption(tmp_path):
    state, hist = _toy_loop(tmp_path, fail_at=6)
    # preempted at 6 -> resumed from step 4 checkpoint -> completed
    assert hist["restarts"] == 1
    assert float(state["x"]) == 10


def test_resilient_loop_gives_up(tmp_path):
    def always_preempt(step):
        raise Preempted("always")

    fcfg = FaultConfig(ckpt_dir=str(tmp_path), max_restarts=2)
    with pytest.raises(RuntimeError, match="max_restarts"):
        run_resilient(lambda s, b: (s, {"loss": 0.0}), {"x": jnp.zeros(())},
                      lambda step: {}, fcfg, num_steps=5,
                      save_fn=lambda s, st: None,
                      restore_fn=lambda: None,
                      preempt_hook=always_preempt)


def test_resilient_loop_save_failure_does_not_burn_restarts(tmp_path):
    """A flaky checkpoint disk is logged under save_failures and training
    continues — with max_restarts=0 any miscounted save failure would
    abort the run."""
    saves = {"n": 0}

    def bad_save(step, state):
        saves["n"] += 1
        raise RuntimeError("checkpoint disk full")

    fcfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_restarts=0)
    state, hist = run_resilient(
        lambda s, b: ({"x": s["x"] + 1}, {"loss": 0.5}),
        {"x": jnp.zeros(())}, lambda step: {}, fcfg, num_steps=6,
        save_fn=bad_save, restore_fn=lambda: None)
    assert float(state["x"]) == 6
    assert hist["restarts"] == 0
    assert hist["save_failures"] == saves["n"] == 3  # steps 2, 4, 6
    assert hist["saves"] == 0


def test_resilient_loop_restore_failure_cold_starts(tmp_path):
    """A restore_fn that raises (corrupt checkpoint) means 'no usable
    checkpoint': the restart goes back to step 0 instead of crashing the
    supervisor."""
    armed = {"on": True}

    def preempt(step):
        if step == 3 and armed["on"]:
            armed["on"] = False
            raise Preempted("sim")

    def bad_restore():
        raise OSError("corrupt checkpoint dir")

    fcfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                       max_restarts=2)
    state, hist = run_resilient(
        lambda s, b: ({"x": s["x"] + 1}, {"loss": 0.5}),
        {"x": jnp.zeros(())}, lambda step: {}, fcfg, num_steps=5,
        save_fn=lambda s, st: None, restore_fn=bad_restore,
        preempt_hook=preempt)
    assert hist["restarts"] == 1
    # cold restart: step counter reset to 0, in-memory state carried on
    # (3 steps before the preemption + 5 after the reset)
    assert float(state["x"]) == 8


def test_resilient_loop_joins_flaky_async_save(tmp_path):
    """An async save handle whose join() raises must be swallowed (and
    always joined — no leak), not take down the run or leak into the
    restart path."""
    joins = {"n": 0}

    class FlakyHandle:
        def join(self):
            joins["n"] += 1
            raise RuntimeError("async save died")

    fcfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=2, max_restarts=0)
    state, hist = run_resilient(
        lambda s, b: ({"x": s["x"] + 1}, {"loss": 0.5}),
        {"x": jnp.zeros(())}, lambda step: {}, fcfg, num_steps=4,
        save_fn=lambda s, st: FlakyHandle(), restore_fn=lambda: None)
    assert float(state["x"]) == 4
    assert hist["saves"] == 2          # both saves were issued...
    assert joins["n"] == 2             # ...and both handles joined
