"""Cross-process serving: coordinator-backed worker pools, process-kill
chaos, quarantine propagation through the shared cache file, and the
pool-lost fallback to the in-process ladder.

These tests spawn REAL worker processes (multiprocessing spawn context)
and kill them with REAL SIGKILLs — no simulation.  The CI
``chaos-multiproc`` lane re-runs them with 8 forced host devices so the
per-worker lane meshes actually span devices."""
import numpy as np
import pytest

from repro.core import dispatch as dp
from repro.core.formats import random_sparse
from repro.runtime import coordinator as coord
from repro.runtime import faultinject as fi
from repro.serving import spgemm_service as svc

N_REQ = 12

CLASSES = [(32, 0.02, "uniform"), (48, 0.05, "uniform"),
           (48, 0.008, "powerlaw"), (64, 0.03, "banded")]


def _mat(n=48, density=0.02, seed=0, pattern="uniform"):
    return random_sparse(n, n, density, seed=seed, pattern=pattern)


def _dense(csr):
    return np.asarray(csr.to_dense(), np.float64)


def _stream(n_req=N_REQ):
    mats = [_mat(n=c[0], density=c[1], pattern=c[2], seed=i)
            for i, c in enumerate(CLASSES)]
    rng = np.random.default_rng(3)
    return [mats[int(rng.integers(len(mats)))] for _ in range(n_req)]


def _run_traffic(cache, coordinator=None, n_req=N_REQ):
    """Drive the fixed request stream through a service (in-process when
    ``coordinator`` is None, pool-dispatched otherwise)."""
    service = svc.SpGemmService(
        cache=cache, max_batch=4, flush_timeout=1e9,
        coordinator=coordinator,
        policy=dp.RetryPolicy(max_attempts=5, backoff_base_s=0.0))
    for m in _stream(n_req):
        service.submit(m, m)
    service.drain()
    return service


@pytest.fixture(scope="module")
def ref_run(tmp_path_factory):
    """The fault-free single-process reference: the bit-exactness oracle
    for every multi-process run of the same stream."""
    cache = dp.AutotuneCache(
        str(tmp_path_factory.mktemp("ref") / "autotune.json"))
    service = _run_traffic(cache)
    assert len(service.completed) == N_REQ and not service.dead_letters
    return {r.id: _dense(r.result) for r in service.completed}


def _wait_task(pool, task_id, timeout=180.0):
    import time
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        for tid, res in pool.poll(timeout=1.0):
            if tid == task_id:
                return res
    raise TimeoutError(f"task {task_id} never completed")


# ---------------------------------------------------------------------------
# payload plumbing
# ---------------------------------------------------------------------------

def test_pack_unpack_csr_round_trip():
    m = _mat(seed=5)
    back = coord.unpack_csr(coord.pack_csr(m))
    assert back.shape == m.shape
    np.testing.assert_array_equal(_dense(back), _dense(m))


def test_remote_flush_payload_carries_policy():
    class _R:
        def __init__(self, m):
            self.A = self.B = m
    p = coord.make_flush_payload(
        [_R(_mat(seed=6))], bucket=("b",), engine="auto", max_batch=4,
        policy=dp.RetryPolicy(max_attempts=7, backoff_base_s=0.125))
    assert p["policy"]["max_attempts"] == 7
    assert p["policy"]["backoff_base_s"] == 0.125
    assert len(p["pairs"]) == 1 and p["max_batch"] == 4


# ---------------------------------------------------------------------------
# the pool, healthy
# ---------------------------------------------------------------------------

def test_multiproc_serving_matches_single_process(tmp_path, ref_run):
    """2-worker pool, no faults: every request completes, results are
    bit-exact vs the in-process run, flush provenance comes from the
    workers."""
    with coord.ProcessCoordinator(
            2, cache_path=str(tmp_path / "mp.json")) as pool:
        service = _run_traffic(
            dp.AutotuneCache(str(tmp_path / "mp.json")), coordinator=pool)
        assert pool.alive_count == 2
    assert len(service.completed) == N_REQ and not service.dead_letters
    for r in service.completed:
        assert r.tier == "planned"
        assert np.array_equal(_dense(r.result), ref_run[r.id]), r.id
    assert service.flush_log and all(f.engine not in ("?", None)
                                     for f in service.flush_log)
    # the pool actually partitioned the lane space at startup
    spawns = [e for e in pool.events if e["event"] == "spawn"]
    assert len(spawns) == 2 and all(e["n_lanes"] >= 1 for e in spawns)


# ---------------------------------------------------------------------------
# the acceptance gate: SIGKILL a worker process mid-flush
# ---------------------------------------------------------------------------

def test_chaos_process_kill_mid_flush(tmp_path, ref_run):
    """THE multi-process chaos acceptance: worker process 0 is SIGKILLed
    mid-flush (a real ``kill_process`` fault inside the spawned process)
    while batched kernel launches fail at a 10% injected rate in every
    worker.  Every submitted id must resolve, availability must be 1.0,
    and planned-tier outputs must be bit-exact vs the fault-free
    single-process run."""
    kernel_chaos = fi.FaultSpec(site="kernel.batched", kind="raise",
                                rate=0.10)
    specs = {
        0: [fi.FaultSpec(site="service.flush", kind="kill_process",
                         max_fires=1), kernel_chaos],
        1: [kernel_chaos],
    }
    with coord.ProcessCoordinator(
            2, cache_path=str(tmp_path / "chaos.json"),
            fault_specs=specs, fault_seed=11,
            max_worker_restarts=1) as pool:
        service = _run_traffic(
            dp.AutotuneCache(str(tmp_path / "chaos.json")),
            coordinator=pool)
        events = [e["event"] for e in pool.events]

    # nothing silently dropped: every submitted id resolves exactly once
    for rid in range(N_REQ):
        r = service.lookup(rid)
        assert r.done, f"request {rid} neither completed nor dead-lettered"
        assert (r.result is not None) != (r.error is not None)
    stats = service.stats()
    assert stats["availability"] == 1.0, stats

    # planned-tier outputs are bit-exact vs the fault-free run — a kill
    # moves *where* a bucket ran, never *what* it computed
    for r in service.completed:
        if r.tier == "planned":
            assert np.array_equal(_dense(r.result), ref_run[r.id]), r.id
        else:
            np.testing.assert_allclose(_dense(r.result), ref_run[r.id],
                                       rtol=1e-4, atol=1e-4)

    # the chaos was real: a worker died and the pool re-partitioned
    assert "worker_lost" in events, events
    assert "remesh" in events, events


def test_hung_worker_is_killed_and_task_requeued(tmp_path):
    """A worker that hangs mid-task (injected ``hang``) is declared lost
    at task_timeout_s, SIGKILLed, and its bucket re-runs on a
    survivor."""
    specs = {0: [fi.FaultSpec(site="service.flush", kind="hang",
                              delay_s=120.0, max_fires=1)]}
    m = _mat(n=32, density=0.02, seed=0)
    with coord.ProcessCoordinator(
            2, cache_path=str(tmp_path / "hang.json"),
            fault_specs=specs, max_worker_restarts=0,
            task_timeout_s=6.0) as pool:
        payload = {"pairs": [(coord.pack_csr(m), coord.pack_csr(m))],
                   "engine": "auto", "max_batch": 4,
                   "policy": {"max_attempts": 2, "backoff_base_s": 0.0}}
        tid = pool.submit(payload, prefer=0)
        res = _wait_task(pool, tid)
        events = [e for e in pool.events if e["event"] == "worker_lost"]
    assert res.get("outcomes") and all(o["ok"] for o in res["outcomes"])
    assert events and "timeout" in events[0]["why"], pool.events


# ---------------------------------------------------------------------------
# quarantine propagation across processes
# ---------------------------------------------------------------------------

def test_quarantine_propagates_across_worker_processes(tmp_path):
    """A combo crashing in worker process A is routed around by worker
    process B without B ever executing it: A's local ladder quarantines
    and pushes to the shared cache file; B's plan miss pulls the poison
    and selects a healthy engine on the first attempt."""
    cache_path = str(tmp_path / "shared.json")
    m = _mat(n=48, density=0.05, seed=1)
    payload = {"pairs": [(coord.pack_csr(m), coord.pack_csr(m))] * 2,
               "engine": "auto", "max_batch": 4,
               "policy": {"max_attempts": 2, "backoff_base_s": 0.0}}
    # worker 0: every *batched* kernel launch dies (planned tier and
    # the whole ladder — isolation is single-pair and survives);
    # worker 1: healthy
    specs = {0: [fi.FaultSpec(site="kernel.batched", kind="raise")]}
    with coord.ProcessCoordinator(
            2, cache_path=cache_path, fault_specs=specs) as pool:
        t1 = pool.submit(dict(payload), prefer=0)
        res1 = _wait_task(pool, t1)
        # A survived on per-request isolation (its batched path is dead)
        # and — the point — pushed the quarantine to the shared file
        assert all(o["ok"] for o in res1["outcomes"])
        assert res1["flush"]["tier"] == "isolated", res1["flush"]

        shared = dp.AutotuneCache(cache_path)
        key = dp.cache_key(m, m)
        poisoned = {e for e, _ in shared.quarantined(key)}
        assert poisoned, "worker A never pushed its quarantine"

        t2 = pool.submit(dict(payload), prefer=1)
        res2 = _wait_task(pool, t2)
    # B planned around the poison: healthy engine, first attempt, no
    # errors — it never executed the quarantined combo
    assert all(o["ok"] for o in res2["outcomes"])
    f2 = res2["flush"]
    assert f2["tier"] == "planned", f2
    assert f2["attempts"] == 1 and not f2["errors"], f2
    assert f2["engine"] not in poisoned, (f2, poisoned)


# ---------------------------------------------------------------------------
# total pool loss: the in-process ladder is the floor
# ---------------------------------------------------------------------------

def test_pool_lost_falls_back_to_local_ladder(tmp_path):
    """1-worker pool with zero restart budget and a kill-on-flush fault:
    the pool dies, and the service serves every request through its own
    in-process ladder anyway."""
    specs = [fi.FaultSpec(site="service.flush", kind="kill_process",
                          max_fires=1)]
    with coord.ProcessCoordinator(
            1, cache_path=str(tmp_path / "lost.json"),
            fault_specs=specs, max_worker_restarts=0) as pool:
        service = _run_traffic(
            dp.AutotuneCache(str(tmp_path / "lost.json")),
            coordinator=pool, n_req=8)
        assert pool.alive_count == 0  # the pool really is gone
    assert len(service.completed) == 8 and not service.dead_letters
    assert service.stats()["availability"] == 1.0
