import os
import sys

# Tests run on the single real CPU device; only the dry-run uses 512
# placeholder devices (and sets its own XLA_FLAGS before jax init).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
