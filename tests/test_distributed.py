"""Multi-device semantics (8 host devices via subprocess): shard_map MoE
vs einsum reference, sharded train step, sharding rules."""
import os
import subprocess
import sys

import pytest

# every test spawns an 8-device subprocess with its own jax init (~10 s
# each) — slow lane only
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run8(body: str) -> str:
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {repr(SRC)})
import jax, numpy as np, jax.numpy as jnp, dataclasses
from repro.configs import base as cb
from repro.distributed import sharding as shd
{body}
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    return r.stdout


def test_zipper_moe_matches_einsum_on_mesh():
    out = _run8("""
from repro.models import moe as moe_mod
cfg = dataclasses.replace(cb.get_smoke_config("arctic_480b"),
                          moe_dispatch="zipper", num_experts=8,
                          capacity_factor=8.0)
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((2, 4), ("data", "model"))
x = jax.random.normal(key, (4, 16, cfg.d_model), jnp.float32)
p = moe_mod.moe_init(key, cfg, jnp.float32)
y_ref, _ = moe_mod.moe_block(p, x, cfg, dispatch="einsum")
with shd.use_mesh(mesh):
    y_sm, _ = jax.jit(lambda p, x: moe_mod.moe_block(p, x, cfg,
                                                     dispatch="zipper"))(p, x)
err = float(jnp.abs(y_ref - y_sm).max())
assert err < 1e-4, err
g = None
with shd.use_mesh(mesh):
    g = jax.jit(jax.grad(lambda p, x: moe_mod.moe_block(
        p, x, cfg, dispatch="zipper")[0].sum()))(p, x)
g_ref = jax.grad(lambda p, x: moe_mod.moe_block(
    p, x, cfg, dispatch="einsum")[0].sum())(p, x)
ge = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
    lambda a, b: float(jnp.abs(a - b).max()), g, g_ref)))
assert ge < 1e-3, ge
print("MOE_MESH_OK")
""")
    assert "MOE_MESH_OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run8("""
from repro.launch import steps as st
from repro.optim import adamw
cfg = cb.get_smoke_config("tinyllama_1_1b")
opt_cfg = adamw.AdamWConfig(lr=1e-3)
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
batch["labels"] = batch["tokens"]
# single device
state0 = st.init_train_state(cfg, opt_cfg, key)
_, m0 = jax.jit(st.make_train_step(cfg, opt_cfg))(state0, batch)
# 2x4 mesh
mesh = jax.make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    shapes = st.train_state_shapes(cfg, opt_cfg)
    sh = st.state_shardings(cfg, shapes)
    state1 = jax.jit(lambda k: st.init_train_state(cfg, opt_cfg, k),
                     out_shardings=sh)(key)
    _, m1 = jax.jit(st.make_train_step(cfg, opt_cfg),
                    in_shardings=(sh, None))(state1, batch)
d = abs(float(m0["loss"]) - float(m1["loss"]))
assert d < 5e-2, (float(m0["loss"]), float(m1["loss"]))
print("TRAIN_MESH_OK", float(m0["loss"]), float(m1["loss"]))
""")
    assert "TRAIN_MESH_OK" in out


def test_param_sharding_rules():
    out = _run8("""
import functools
from repro.models import model as M
cfg = cb.get_smoke_config("deepseek_v2_236b")
mesh = jax.make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    shapes = jax.eval_shape(functools.partial(M.init_params, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    sh = shd.param_shardings(shapes, fsdp=False)
    # embed vocab -> model
    assert "model" in str(sh["embed"]["w"].spec), sh["embed"]["w"].spec
    # stacked group params lead with None
    spec = sh["g0"]["s0"]["ffn"]["experts"]["w1"].spec
    assert spec[0] is None and "model" in str(spec), spec
print("RULES_OK")
""")
    assert "RULES_OK" in out


def test_decode_seq_sharded_cache():
    """Decode with the KV-cache sequence dim sharded over the model axis
    (flash-decode partial softmax via GSPMD) matches single-device."""
    out = _run8("""
from repro.models import model as M
from repro.launch import steps as st
cfg = cb.get_smoke_config("granite_3_2b")
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
cache = M.init_cache(cfg, 4, 32)
lg0, c0 = M.prefill(params, cfg, toks, cache)
d0, _ = M.decode_step(params, cfg, toks[:, :1], c0, jnp.int32(16))
mesh = jax.make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    cache = M.init_cache(cfg, 4, 32)
    c_sh = st.cache_shardings(jax.eval_shape(lambda: cache))
    cache = jax.device_put(cache, c_sh)
    lg1, c1 = jax.jit(lambda p, t, c: M.prefill(p, cfg, t, c))(params, toks, cache)
    d1, _ = jax.jit(lambda p, t, c: M.decode_step(p, cfg, t, c, jnp.int32(16)))(params, toks[:, :1], c1)
err = float(jnp.abs(jnp.asarray(d0, jnp.float32) - jnp.asarray(d1, jnp.float32)).max())
assert err < 0.1, err
print("DECODE_MESH_OK", err)
""")
    assert "DECODE_MESH_OK" in out
