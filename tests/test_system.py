"""End-to-end behaviour: real training runs, resume-equivalence, serving."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import base as cb
from repro.launch.train import train
from repro.optim import adamw
from repro.runtime.fault import FaultConfig, Preempted

# real multi-step training runs + serving loops: seconds to tens of seconds
# each — CI runs these in the non-blocking slow lane, not the tier-1 gate
pytestmark = pytest.mark.slow


def _run(arch, tmp_path, steps=12, preempt_hook=None, ckpt_every=4,
         lr=1e-3):
    cfg = cb.get_smoke_config(arch)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=2, decay_steps=steps)
    fcfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                       async_save=False)
    # seed pinned explicitly: the loss-decrease assertions below are
    # margin tests, and the slow lane must be deterministic
    return train(cfg, opt_cfg, fcfg, num_steps=steps, global_batch=4,
                 seq_len=32, preempt_hook=preempt_hook, log_every=1000,
                 seed=0)


def test_train_loss_decreases(tmp_path):
    # 25 steps at lr=1e-3 was borderline on CPU (drop ~= the 0.1 margin);
    # 40 steps at lr=3e-3 drops ~0.32 on the pinned seed — 3x the margin
    _, hist = _run("tinyllama_1_1b", tmp_path, steps=40, lr=3e-3)
    losses = [h["loss"] for h in hist["steps"]]
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_train_moe_loss_decreases(tmp_path):
    cfg = cb.get_smoke_config("arctic_480b")
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=3, decay_steps=40)
    fcfg = FaultConfig(ckpt_dir=str(tmp_path), ckpt_every=40,
                       async_save=False)
    _, hist = train(cfg, opt_cfg, fcfg, num_steps=40, global_batch=4,
                    seq_len=32, log_every=1000)
    losses = [h["loss"] for h in hist["steps"]]
    assert losses[-1] < losses[0] - 0.02, (losses[0], losses[-1])


def test_preemption_mid_run_resumes_and_finishes(tmp_path):
    fired = {"done": False}

    def preempt(step):
        if step == 9 and not fired["done"]:
            fired["done"] = True
            raise Preempted("sim")

    state, hist = _run("qwen1_5_0_5b", tmp_path, steps=12,
                       preempt_hook=preempt)
    assert hist["restarts"] == 1
    assert int(state["opt"]["step"]) == 12


def test_resume_bitwise_equivalence(tmp_path):
    """Train 8; vs train 4 -> kill -> resume to 8: identical params.

    Holds because the data pipeline is deterministic in (seed, step) and the
    checkpoint captures the full optimizer state."""
    a, _ = _run("granite_3_2b", tmp_path / "a", steps=8, ckpt_every=8)

    fired = {"done": False}

    def preempt(step):
        if step == 6 and not fired["done"]:
            fired["done"] = True
            raise Preempted("sim")

    b, _ = _run("granite_3_2b", tmp_path / "b", steps=8, ckpt_every=4,
                preempt_hook=preempt)
    fa = jax.tree_util.tree_leaves(a["params"])
    fb = jax.tree_util.tree_leaves(b["params"])
    for la, lb in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_elastic_reshard_subprocess(tmp_path):
    """Save on a 1-device mesh, restore on an 8-device (2,4) mesh."""
    from repro.checkpoint import ckpt
    from repro.models import model as M
    cfg = cb.get_smoke_config("tinyllama_1_1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ckpt.save(str(tmp_path), 1, params)
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
import sys
sys.path.insert(0, {repr(os.path.join(os.path.dirname(__file__), '..', 'src'))})
from repro.checkpoint import ckpt
from repro.configs import base as cb
from repro.distributed import sharding as shd
from repro.models import model as M
import functools
cfg = cb.get_smoke_config("tinyllama_1_1b")
mesh = jax.make_mesh((2, 4), ("data", "model"))
with shd.use_mesh(mesh):
    shapes = jax.eval_shape(functools.partial(M.init_params, cfg),
                            jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
    sh = shd.param_shardings(shapes, False)
    params = ckpt.restore({repr(str(tmp_path))}, shapes, shardings=sh)
    lg, _, _ = jax.jit(lambda p, t: M.forward(p, cfg, t))(params,
        jax.numpy.zeros((2, 16), jax.numpy.int32))
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    srt = params["embed"]["w"].sharding
    assert len(srt.device_set) == 8, srt
print("RESHARD_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300)
    assert "RESHARD_OK" in r.stdout, r.stdout + r.stderr


def test_serving_engine_greedy_deterministic():
    from repro.serving.engine import Engine, Request
    from repro.models import model as M
    cfg = cb.get_smoke_config("qwen1_5_0_5b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_seq=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(2)]
    r1 = eng.generate([Request(p.copy(), 8) for p in prompts])
    r2 = eng.generate([Request(p.copy(), 8) for p in prompts])
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.out, b.out)


def test_zipper_topk_matches_numpy():
    from repro.serving.sampler import zipper_topk
    rng = np.random.default_rng(1)
    shards = [rng.standard_normal(64).astype(np.float32) for _ in range(4)]
    vals, ids = zipper_topk(shards, k=8)
    full = np.concatenate(shards)
    want = np.sort(full)[::-1][:8]
    np.testing.assert_allclose(np.sort(vals)[::-1], want, rtol=1e-5)
