"""The hillclimb knobs must be numerically transparent: every perf flag
produces the same math as the baseline (sharding/layout/traffic changes
only)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cb
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _setup(arch, **over):
    cfg = dataclasses.replace(cb.get_smoke_config(arch), **over)
    p = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                              cfg.vocab_size)
    return cfg, p, toks


@pytest.mark.slow  # four loss/grad compiles of the full model (~13 s)
def test_ce_chunk_matches_full():
    cfg0, p, toks = _setup("tinyllama_1_1b")
    cfg1 = dataclasses.replace(cfg0, ce_chunk=4)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = M.loss_fn(p, cfg0, batch)
    l1, _ = M.loss_fn(p, cfg1, batch)
    assert abs(float(l0) - float(l1)) < 1e-3
    g0 = jax.grad(lambda p: M.loss_fn(p, cfg0, batch)[0])(p)
    g1 = jax.grad(lambda p: M.loss_fn(p, cfg1, batch)[0])(p)
    err = max(jax.tree_util.tree_leaves(jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                   b.astype(jnp.float32)).max()), g0, g1)))
    assert err < 5e-2, err


@pytest.mark.slow  # prefill+decode compiles for two full archs (~25 s)
@pytest.mark.parametrize("arch", ["granite_3_2b", "deepseek_v2_236b"])
def test_decode_dus_matches_onehot(arch):
    cfg0, p, toks = _setup(arch)
    cfg1 = dataclasses.replace(cfg0, decode_dus=True)
    cache0 = M.init_cache(cfg0, 2, 32)
    cache1 = M.init_cache(cfg1, 2, 32)
    _, cache0 = M.prefill(p, cfg0, toks, cache0)
    _, cache1 = M.prefill(p, cfg1, toks, cache1)
    d0, _ = M.decode_step(p, cfg0, toks[:, :1], cache0, jnp.int32(16))
    d1, _ = M.decode_step(p, cfg1, toks[:, :1], cache1, jnp.int32(16))
    np.testing.assert_allclose(np.asarray(d0, np.float32),
                               np.asarray(d1, np.float32), atol=1e-5)


def test_layer_layout_sp_matches_tp():
    cfg0, p, toks = _setup("tinyllama_1_1b")
    cfg1 = dataclasses.replace(cfg0, layer_layout="sp")
    batch = {"tokens": toks, "labels": toks}
    l0, _ = M.loss_fn(p, cfg0, batch)
    l1, _ = M.loss_fn(p, cfg1, batch)
    assert float(l0) == float(l1)  # no mesh: constraints are no-ops


def test_attn_block_skip_matches():
    cfg0, p, toks = _setup("tinyllama_1_1b")
    cfg1 = dataclasses.replace(cfg0, attn_block_skip=True, attn_q_block=8,
                               attn_kv_block=8)
    lg0, _, _ = M.forward(p, cfg0, toks)
    lg1, _, _ = M.forward(p, cfg1, toks)
    np.testing.assert_allclose(np.asarray(lg0, np.float32),
                               np.asarray(lg1, np.float32), atol=2e-2)


def test_prefill_cache_seqshard_matches():
    cfg0, p, toks = _setup("qwen1_5_0_5b")
    cfg1 = dataclasses.replace(cfg0, prefill_cache_seqshard=True)
    c0 = M.init_cache(cfg0, 2, 32)
    c1 = M.init_cache(cfg1, 2, 32)
    lg0, c0 = M.prefill(p, cfg0, toks, c0)
    lg1, c1 = M.prefill(p, cfg1, toks, c1)
    np.testing.assert_array_equal(np.asarray(lg0, np.float32),
                                  np.asarray(lg1, np.float32))
