"""Lane-sharded batched SpGEMM: balanced assignment properties and
bit-exact equivalence with the single-device batched path.

These tests adapt to the visible device count: on a 1-device CPU they
exercise the full code path over a trivial mesh; the CI multi-device
lane runs them under XLA_FLAGS=--xla_force_host_platform_device_count=8
where the shard_map actually spans 8 devices. One slow test forces the
8-device case in a subprocess regardless of the parent's device count."""
import os
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core.formats import batch_csr, random_sparse
from repro.distributed import spgemm_shard as shard
from repro.launch.mesh import make_lane_mesh


@pytest.fixture
def cache(tmp_path):
    return dp.AutotuneCache(str(tmp_path / "autotune.json"))


def _mixed_batch(seed=0):
    """Mixed densities/patterns -> very skewed per-lane work."""
    specs = [(0.004, "uniform"), (0.05, "uniform"), (0.02, "powerlaw"),
             (0.03, "banded"), (0.01, "uniform"), (0.04, "powerlaw")]
    return [random_sparse(64, 64, d, seed=seed + i, pattern=p)
            for i, (d, p) in enumerate(specs)]


def _assert_bit_equal(a, b):
    for name in ("indptr", "indices", "data", "valid"):
        assert np.array_equal(np.asarray(getattr(a, name)),
                              np.asarray(getattr(b, name))), name


# ---------------------------------------------------------------------------
# assignment
# ---------------------------------------------------------------------------

def test_assign_lanes_is_balanced():
    """LPT keeps the max device load within 2x of the ideal split (the
    classic 4/3 bound, loosened for integer lane counts)."""
    rng = np.random.default_rng(0)
    works = rng.zipf(1.5, 64) * 100
    for n_dev in (2, 4, 8):
        dev = shard.assign_lanes(works, n_dev)
        loads = np.bincount(dev, weights=works, minlength=n_dev)
        counts = np.bincount(dev, minlength=n_dev)
        assert counts.max() <= -(-len(works) // n_dev)
        ideal = works.sum() / n_dev
        # greedy makespan bound: never worse than ideal + one heaviest lane
        assert loads.max() <= ideal + works.max()


def test_assign_lanes_respects_slot_cap():
    dev = shard.assign_lanes(np.array([5, 4, 3, 2, 1, 0]), 3)
    assert np.bincount(dev, minlength=3).max() == 2


def test_shard_plan_layout(cache):
    mats = _mixed_batch()
    A = batch_csr(mats, batch_cap=8)
    sp = shard.plan_sharded(A, A, "esc", cache=cache)
    assert sp.n_dev == len(jax.devices())
    assert sp.n_slots == sp.n_dev * sp.lanes_per_dev
    assert sorted(set(sp.slot_of_lane)) == sorted(sp.slot_of_lane)  # 1:1
    assert len(sp.works) == A.batch
    assert sum(sp.device_loads()) == sum(sp.works)


# ---------------------------------------------------------------------------
# bit-exact equivalence vs the single-device batched path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["esc", "spz", "spz-rsort", "auto"])
def test_sharded_matches_batched_bit_exact(engine, cache):
    mats = _mixed_batch()
    A = batch_csr(mats, batch_cap=8)  # two invalid padding lanes
    kw = {"R": 8, "S": 32} if engine.startswith("spz") else {}
    ref = dp.spgemm_batched(A, A, engine=engine, cache=cache, **kw)
    got = shard.spgemm_batched_sharded(A, A, engine=engine, cache=cache,
                                       **kw)
    _assert_bit_equal(ref, got)


def test_sharded_results_match_oracle(cache):
    mats = _mixed_batch(seed=3)
    A = batch_csr(mats)
    out = shard.spgemm_batched_sharded(A, A, engine="esc", cache=cache)
    for i, m in enumerate(mats):
        want = np.asarray(sg.spgemm_scl_array(m, m).to_dense(), np.float64)
        np.testing.assert_allclose(np.asarray(out[i].to_dense(), np.float64),
                                   want, rtol=1e-4, atol=1e-4)


def test_sharded_plan_reuse(cache):
    """One ShardPlan executes repeatedly (the service flush path)."""
    mats = _mixed_batch(seed=5)
    A = batch_csr(mats)
    sp = shard.plan_sharded(A, A, "esc", cache=cache)
    a = shard.execute_sharded(sp, A, A)
    b = shard.execute_sharded(sp, A, A)
    _assert_bit_equal(a, b)


def test_sharded_rejects_mismatched_operands(cache):
    A = batch_csr(_mixed_batch())
    B = batch_csr(_mixed_batch()[:3])
    sp = shard.plan_sharded(A, A, "esc", cache=cache)
    with pytest.raises(ValueError, match="mismatch"):
        shard.execute_sharded(sp, B, B)
    # an all-invalid operand pair fails with the same clean error as the
    # single-device path, not a raw max()-of-empty crash in assembly
    import jax.numpy as jnp
    from repro.core.formats import BatchedCSR
    dead = BatchedCSR(A.indptr, A.indices, A.data,
                      jnp.zeros(A.batch, bool), A.shape)
    with pytest.raises(ValueError, match="no valid lanes"):
        shard.execute_sharded(sp, dead, dead)


def test_lane_mesh_shape():
    mesh = make_lane_mesh()
    assert mesh.axis_names == ("lanes",)
    assert mesh.shape["lanes"] == len(jax.devices())


# ---------------------------------------------------------------------------
# forced 8-device equivalence (subprocess; slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_matches_batched_on_8_devices():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {repr(src)})
import numpy as np, jax, tempfile
from repro.core import dispatch as dp
from repro.core.formats import batch_csr, random_sparse
from repro.distributed import spgemm_shard as shard
assert len(jax.devices()) == 8
cache = dp.AutotuneCache(tempfile.mkdtemp() + "/c.json")
mats = [random_sparse(64, 64, d, seed=i, pattern=p)
        for i, (d, p) in enumerate([(0.004, "uniform"), (0.05, "uniform"),
                                    (0.02, "powerlaw"), (0.03, "banded"),
                                    (0.01, "uniform"), (0.04, "powerlaw")])]
A = batch_csr(mats, batch_cap=10)
for eng in ("esc", "spz", "auto"):
    ref = dp.spgemm_batched(A, A, engine=eng, cache=cache)
    sp = shard.plan_sharded(A, A, engine=eng, cache=cache)
    assert sp.n_dev == 8
    got = shard.execute_sharded(sp, A, A)
    for name in ("indptr", "indices", "data", "valid"):
        assert np.array_equal(np.asarray(getattr(ref, name)),
                              np.asarray(getattr(got, name))), (eng, name)
print("SHARD8_OK")
"""
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600)
    assert "SHARD8_OK" in r.stdout, r.stdout + r.stderr
