"""Learned cost-model dispatch: dataset logging, training, the model
selection source, quarantine TTL, and cache-schema migration.

Covers the selection ladder end to end — autotune sweeps log full timing
vectors + features, the offline-trained model plans with
``source="model"`` on unseen buckets, low confidence falls through to
measurement/heuristics — plus the satellites: quarantine TTL/re-probe
backoff under a fake clock, forward migration of hand-written v1 cache
files, ``extract_features`` invariants (hypothesis, when installed),
and the ``tools/dump_autotune.py`` maintenance CLI.
"""
import json
import math
import os
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import dispatch as dp
from repro.core.formats import csr_from_coo, random_sparse
from repro.models import dispatch_model as dm


@pytest.fixture
def cache(tmp_path):
    return dp.AutotuneCache(str(tmp_path / "autotune.json"))


def _mats(n=32, density=0.02, seed=0):
    return (random_sparse(n, n, density, seed=seed),
            random_sparse(n, n, density, seed=seed + 1000))


def _sweep(cache, sizes=(24, 48, 96), density=0.02):
    """Populate ``cache`` with autotune sweeps (timings + features)."""
    for i, n in enumerate(sizes):
        A, B = _mats(n, density, seed=i)
        dp.plan(A, B, autotune=True, cache=cache, model=False)


def _toy_samples(n=16, seed=0):
    """Synthetic dataset with a clean size-dependent winner crossover."""
    rng = np.random.default_rng(seed)
    samples = []
    for i in range(n):
        work = float(2 ** rng.uniform(6, 18))
        feats = {"nnz": work / 8, "density": min(0.5, work / 1e7),
                 "avg_work_per_row": work / 64,
                 "avg_work_per_group": work / 8,
                 "work_var_per_group": float(rng.uniform(0, 2)),
                 "total_work": work}
        samples.append({"key": f"b{i}", "features": feats, "timings": {
            "esc|": (1e-5 + 2e-9 * work) * rng.lognormal(0, 0.03),
            "scl-hash|": (2e-6 + 6e-8 * work) * rng.lognormal(0, 0.03),
        }})
    return samples


# ---------------------------------------------------------------------------
# dataset logging: sweeps record timing vectors + features
# ---------------------------------------------------------------------------

def test_autotune_sweep_logs_timings_and_features(cache):
    A, B = _mats()
    p = dp.plan(A, B, autotune=True, cache=cache, model=False)
    assert p.source == "autotune"
    entry = cache.get(p.cache_key)
    assert entry["engine"] == p.engine
    combos = set(entry["timings"])
    # every measurable candidate that survived is in the vector, winner
    # included, and every timing is a positive finite float
    assert dp.combo_str(p.engine, entry.get("backend")) in combos
    assert len(combos) >= 3
    assert all(t > 0 and math.isfinite(t)
               for t in entry["timings"].values())
    feats = entry["features"]
    assert set(feats) == set(dm.FEATURE_NAMES)
    # heuristic puts stay field-minimal (existing equality tests rely
    # on the exact dict shape)
    A2, B2 = _mats(40, 0.002, seed=9)
    p2 = dp.plan(A2, B2, cache=cache, model=False)
    assert cache.get(p2.cache_key) == {"engine": p2.engine,
                                       "source": "heuristic"}


def test_samples_from_entries_filters_reserved_and_partial(cache):
    _sweep(cache, sizes=(24, 48))
    A, B = _mats(64, 0.002, seed=3)
    dp.plan(A, B, cache=cache, model=False)           # winner-only entry
    cache.quarantine("somekey", "esc", None, reason="x")
    samples = dm.samples_from_entries(cache.entries())
    assert len(samples) == 2
    for s in samples:
        assert not s["key"].startswith("!")
        assert s["timings"] and s["features"]


# ---------------------------------------------------------------------------
# model: training, selection, confidence, persistence
# ---------------------------------------------------------------------------

def test_model_learns_crossover_and_calibrates():
    samples = _toy_samples(24)
    m = dm.DispatchModel.train(samples, steps=250)
    hits = 0
    for s in samples:
        oracle = min(s["timings"], key=s["timings"].get)
        sel = m.select(s["features"], allowed=set(s["timings"]))
        hits += sel.combo == oracle
        assert 0.0 <= sel.confidence <= 1.0
        assert set(sel.costs) == set(s["timings"])
    assert hits >= 20  # near-oracle on a clean synthetic crossover


def test_model_select_respects_allowed_and_abstains():
    m = dm.DispatchModel.train(_toy_samples(12), steps=100)
    feats = _toy_samples(1)[0]["features"]
    only = m.select(feats, allowed={"esc|"})
    assert only.combo == "esc|" and only.confidence == 1.0
    # a combo the model never saw cannot be ranked: not confident
    sel = m.select(feats, allowed={"esc|", "scl-hash|", "mystery|"})
    assert not sel.confident
    assert m.select(feats, allowed={"mystery|"}) is None
    assert m.select(feats, allowed=set()) is None


def test_model_artifact_roundtrip_and_versioning(tmp_path):
    path = str(tmp_path / "cache.json") + dp.MODEL_SUFFIX
    entries = {s["key"]: {"engine": "esc", "source": "autotune",
                          "timings": s["timings"],
                          "features": s["features"]}
               for s in _toy_samples(10)}
    m1 = dm.train_and_save(entries, path, steps=60)
    assert m1.version == 1 and os.path.exists(path)
    m2 = dm.DispatchModel.load(path)
    np.testing.assert_allclose(m2.w, m1.w)
    assert m2.candidates == m1.candidates
    assert m2.sigma == pytest.approx(m1.sigma)
    # retrain bumps the artifact version past the existing one
    m3 = dm.train_and_save(entries, path, steps=60)
    assert m3.version == 2
    # artifacts from a future format refuse to load
    blob = json.loads(open(path).read())
    blob["format_version"] = dm.FORMAT_VERSION + 1
    open(path, "w").write(json.dumps(blob))
    with pytest.raises(ValueError, match="format_version"):
        dm.DispatchModel.load(path)


def test_train_empty_and_degenerate():
    assert dm.DispatchModel.train([]) is None
    # single sample / single candidate still trains and selects
    s = _toy_samples(1)
    s[0]["timings"] = {"esc|": 1e-4}
    m = dm.DispatchModel.train(s, steps=30)
    sel = m.select(s[0]["features"])
    assert sel.engine == "esc" and sel.backend is None


# ---------------------------------------------------------------------------
# dispatch integration: the "model" selection source
# ---------------------------------------------------------------------------

def test_plan_uses_confident_model(cache):
    _sweep(cache)
    model = dm.train_and_save(cache.entries(), dp.model_path_for(cache),
                              steps=150)
    assert model is not None
    model.confidence_floor = 0.0          # force the prediction through
    A, B = _mats(64, 0.02, seed=77)       # unseen bucket
    p = dp.plan(A, B, cache=cache, model=model)
    assert p.source == "model"
    assert p.engine in dp.available_engines()
    # the model path must not write a selection entry — the bucket stays
    # open for a real measurement later
    assert cache.get(p.cache_key) is None
    # executing the model-selected plan is still a correct product
    out = dp.execute(p, A, B)
    ref = np.asarray(A.to_dense(), np.float64) @ \
        np.asarray(B.to_dense(), np.float64)
    np.testing.assert_allclose(np.asarray(out.to_dense(), np.float64),
                               ref, rtol=1e-3, atol=1e-3)


def test_plan_low_confidence_falls_through(cache):
    _sweep(cache)
    model = dm.train_and_save(cache.entries(), dp.model_path_for(cache),
                              steps=150)
    model.confidence_floor = 1.1          # nothing can clear the floor
    A, B = _mats(64, 0.02, seed=78)
    p = dp.plan(A, B, cache=cache, model=model)
    assert p.source == "heuristic"
    # ... and with autotune=True the fallback is a measurement that
    # feeds the dataset
    A2, B2 = _mats(80, 0.02, seed=79)
    p2 = dp.plan(A2, B2, autotune=True, cache=cache, model=model)
    assert p2.source == "autotune"
    assert cache.get(p2.cache_key)["timings"]


def test_plan_model_auto_loads_artifact_and_cache_wins(cache):
    _sweep(cache)
    model = dm.train_and_save(cache.entries(), dp.model_path_for(cache),
                              steps=150, confidence_floor=0.0)
    A, B = _mats(64, 0.02, seed=80)
    p = dp.plan(A, B, cache=cache)        # model="auto" default
    assert p.source == "model"
    # a cache hit still beats the model
    A0, B0 = _mats(24, 0.02, seed=0)      # swept bucket
    assert dp.plan(A0, B0, cache=cache).source == "cache"
    # disabling the model restores the heuristic path
    assert dp.plan(A, B, cache=cache, model=False).source == "heuristic"
    assert model is not None


def test_model_is_quarantine_aware(cache):
    _sweep(cache)
    model = dm.train_and_save(cache.entries(), dp.model_path_for(cache),
                              steps=150, confidence_floor=0.0)
    A, B = _mats(64, 0.02, seed=81)
    first = dp.plan(A, B, cache=cache, model=model)
    assert first.source == "model"
    cache.quarantine(first.cache_key, first.engine, first.backend,
                     reason="crash")
    again = dp.plan(A, B, cache=cache, model=model)
    assert (again.engine, again.backend) != (first.engine, first.backend)


def test_plan_batched_model_source(cache):
    from repro.core.formats import batch_csr
    _sweep(cache)
    dm.train_and_save(cache.entries(), dp.model_path_for(cache),
                      steps=150, confidence_floor=0.0)
    lanes = [random_sparse(64, 64, 0.02, seed=90 + i) for i in range(3)]
    A = batch_csr(lanes, batch_cap=len(lanes))
    p = dp.plan_batched(A, A, cache=cache)
    assert p.source == "model"
    assert p.engine in dp._BATCH_DRIVERS


def test_explain_surfaces_model(cache):
    _sweep(cache)
    dm.train_and_save(cache.entries(), dp.model_path_for(cache), steps=150)
    A, B = _mats(64, 0.02, seed=82)
    info = dp.explain(A, B, cache=cache)
    mi = info["model"]
    assert mi is not None
    assert mi["engine"] and 0.0 <= mi["confidence"] <= 1.0
    assert isinstance(mi["confident"], bool)
    assert all(t > 0 for t in mi["costs"].values())
    assert mi["version"] == 1
    # without an artifact the sub-dict is None, not an error
    other = dp.AutotuneCache(str(os.path.dirname(cache.path))
                             + "/other.json")
    assert dp.explain(A, B, cache=other)["model"] is None


def test_corrupt_artifact_never_fails_a_plan(cache):
    _sweep(cache)
    with open(dp.model_path_for(cache), "w") as f:
        f.write("{not json")
    A, B = _mats(64, 0.02, seed=83)
    p = dp.plan(A, B, cache=cache)        # model="auto" on corrupt file
    assert p.source in ("heuristic", "cache")


def test_serving_plan_hit_counts_model_source():
    from repro.serving.spgemm_service import FlushRecord
    base = dict(bucket=(1,), n_requests=1, reason="full", t=0.0,
                wall_s=0.0, engine="esc")
    assert FlushRecord(source="cache", **base).plan_hit
    assert FlushRecord(source="model", **base).plan_hit
    assert not FlushRecord(source="heuristic", **base).plan_hit


# ---------------------------------------------------------------------------
# quarantine TTL / re-probe budget
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self, t=1_000_000.0):
        self.t = t

    def __call__(self):
        return self.t


def test_quarantine_expires_after_ttl(tmp_path):
    clk = _Clock()
    c = dp.AutotuneCache(str(tmp_path / "c.json"), quarantine_ttl_s=100,
                         clock=clk)
    c.quarantine("bucket", "esc", "xla", reason="oom")
    assert c.is_quarantined("bucket", "esc", "xla")
    clk.t += 99
    assert c.is_quarantined("bucket", "esc", "xla")
    clk.t += 2   # past the TTL: re-admitted for a re-probe
    assert not c.is_quarantined("bucket", "esc", "xla")
    assert c.quarantined("bucket") == []


def test_quarantine_reprobe_backoff_doubles(tmp_path):
    clk = _Clock()
    c = dp.AutotuneCache(str(tmp_path / "c.json"), quarantine_ttl_s=100,
                         clock=clk)
    c.quarantine("bucket", "esc", None)
    clk.t += 101
    assert not c.is_quarantined("bucket", "esc")    # first re-probe
    c.quarantine("bucket", "esc", None)             # crashed again
    clk.t += 101
    assert c.is_quarantined("bucket", "esc")        # 2 strikes: TTL x2
    clk.t += 100
    assert not c.is_quarantined("bucket", "esc")
    # backoff is capped at 16x the base TTL
    for _ in range(8):
        c.quarantine("bucket", "esc", None)
    clk.t += 100 * 16 + 1
    assert not c.is_quarantined("bucket", "esc")


def test_quarantine_expiry_persists_and_merges(tmp_path):
    clk = _Clock()
    path = str(tmp_path / "c.json")
    c = dp.AutotuneCache(path, quarantine_ttl_s=100, clock=clk)
    c.quarantine("bucket", "esc", "xla")
    c.quarantine("bucket", "scl-hash", None)
    clk.t += 101
    assert not c.is_quarantined("bucket", "esc", "xla")
    c.put("other", "esc", "heuristic")   # flush persists the expiry
    c2 = dp.AutotuneCache(path, quarantine_ttl_s=100, clock=clk)
    assert not c2.is_quarantined("bucket", "esc", "xla")
    assert not c2.is_quarantined("bucket", "scl-hash")
    # strike counts survive expiry on disk so the backoff keeps history
    raw = json.load(open(path))
    assert raw["!quarantine:bucket"]["strikes"]["esc|xla"] == 1


def test_plan_reprobes_expired_combo(tmp_path):
    """End to end: a transiently-crashing winner is re-admitted to the
    sweep after its TTL instead of being poisoned forever."""
    clk = _Clock()
    c = dp.AutotuneCache(str(tmp_path / "ttl.json"), quarantine_ttl_s=50,
                         clock=clk)
    A, B = _mats(32, 0.02, seed=5)
    p = dp.plan(A, B, autotune=True, cache=c, model=False)
    combo = dp.combo_str(p.engine, p.backend)
    c.quarantine(p.cache_key, p.engine, p.backend, reason="transient")
    p2 = dp.plan(A, B, autotune=True, cache=c, model=False)
    assert (p2.engine, p2.backend) != (p.engine, p.backend)
    assert combo not in c.get(p2.cache_key)["timings"]
    # the replacement crashes too: the bucket loses its selection entry
    c.quarantine(p2.cache_key, p2.engine, p2.backend, reason="transient")
    clk.t += 51   # both past the TTL — re-admitted to the sweep
    p3 = dp.plan(A, B, autotune=True, cache=c, model=False)
    assert combo in c.get(p3.cache_key)["timings"]


# ---------------------------------------------------------------------------
# schema migration: v1 winner-only files survive the version bump
# ---------------------------------------------------------------------------

def test_v1_cache_file_migrates_forward(tmp_path):
    """Hand-written old-format file: no !schema record, winner-only
    entries, quarantine without timestamps.  Nothing may be dropped."""
    path = str(tmp_path / "old.json")
    v1 = {
        "32x32@7*32x32@7|bk=auto": {"engine": "esc", "source": "autotune",
                                    "backend": "xla"},
        "8x8@4*8x8@4|bk=auto": {"engine": "scl-hash",
                                "source": "heuristic"},
        "!quarantine:32x32@7*32x32@7|bk=auto": {"combos": ["spz|xla"]},
    }
    json.dump(v1, open(path, "w"))
    c = dp.AutotuneCache(path, quarantine_ttl_s=100, clock=_Clock())
    assert c.get("32x32@7*32x32@7|bk=auto") == {
        "engine": "esc", "source": "autotune", "backend": "xla"}
    assert c.get("8x8@4*8x8@4|bk=auto") == {"engine": "scl-hash",
                                            "source": "heuristic"}
    assert c.loaded_schema_version == 1
    # unstamped v1 quarantine combos get a full TTL from load time
    assert c.is_quarantined("32x32@7*32x32@7|bk=auto", "spz", "xla")
    c.put("new", "esc", "heuristic")     # flush rewrites at v2
    raw = json.load(open(path))
    assert raw["!schema"]["version"] == dp.SCHEMA_VERSION
    assert raw["32x32@7*32x32@7|bk=auto"]["engine"] == "esc"
    assert "ts" in raw["!quarantine:32x32@7*32x32@7|bk=auto"]
    # and a fresh reader sees everything
    c2 = dp.AutotuneCache(path)
    assert c2.get("8x8@4*8x8@4|bk=auto")["engine"] == "scl-hash"
    assert c2.loaded_schema_version == dp.SCHEMA_VERSION


def test_merge_preserves_v1_entries_from_disk(tmp_path):
    """A v2 process flushing over a file an old (v1) process wrote must
    merge the old winner entries, not discard them."""
    path = str(tmp_path / "shared.json")
    c = dp.AutotuneCache(path)
    c.put("mine", "esc", "autotune", backend="xla",
          timings={"esc|xla": 1e-4}, features={"nnz": 10})
    # an old process rewrites the file without schema/timings
    json.dump({"theirs": {"engine": "spz", "source": "autotune"}},
              open(path, "w"))
    c.put("mine2", "scl-hash", "heuristic")   # triggers read-merge-write
    raw = json.load(open(path))
    assert raw["theirs"] == {"engine": "spz", "source": "autotune"}
    assert raw["mine"]["timings"] == {"esc|xla": 1e-4}
    assert raw["!schema"]["version"] == dp.SCHEMA_VERSION


def test_merge_unions_timing_vectors(tmp_path):
    """Two processes sweeping the same bucket with different healthy
    candidates: the flush merge unions their timing vectors instead of
    letting the last writer win."""
    path = str(tmp_path / "shared.json")
    a = dp.AutotuneCache(path)
    b = dp.AutotuneCache(path)
    a.put("k", "esc", "autotune", timings={"esc|": 1e-4},
          features={"nnz": 10})
    b.put("k", "esc", "autotune", timings={"esc|": 2e-4, "spz|xla": 5e-4},
          features={"nnz": 10})
    a.refresh()
    merged = a.get("k")["timings"]
    assert set(merged) == {"esc|", "spz|xla"}


# ---------------------------------------------------------------------------
# extract_features invariants
# ---------------------------------------------------------------------------

def _feat_invariants(A, B):
    f1 = dp.extract_features(A, B)
    assert set(f1) == set(dm.FEATURE_NAMES)
    assert all(math.isfinite(float(v)) for v in f1.values())
    # deterministic across calls (memoized and recomputed paths agree)
    assert dp.extract_features(A, B) == f1
    dp.clear_feature_cache()
    assert dp.extract_features(A, B) == f1
    # stable across duplicate CSR wrappers over the SAME buffers: the
    # _OperandMemo keys on buffer identity, a fresh wrapper re-computes
    from repro.core.formats import CSR
    A2 = CSR(A.indptr, A.indices, A.data, A.shape)
    assert dp.extract_features(A2, B) == f1
    # mutating a copy's structure changes features through the memo too
    assert all(dm.featurize(f1)[i] is not None
               for i in range(len(dm.FEATURE_NAMES)))


def test_features_empty_and_single_row():
    empty = csr_from_coo([], [], [], (8, 8))
    _feat_invariants(empty, empty)
    assert dp.extract_features(empty, empty)["nnz"] == 0
    one = csr_from_coo([0, 0], [1, 3], [1.0, 2.0], (1, 8))
    other = random_sparse(8, 8, 0.1, seed=1)
    _feat_invariants(one, other)
    assert dp.extract_features(one, other)["nnz"] == 2


def test_features_regular_matrix():
    A = random_sparse(32, 32, 0.05, seed=3)
    B = random_sparse(32, 32, 0.05, seed=4)
    _feat_invariants(A, B)


if HAVE_HYPOTHESIS:
    @st.composite
    def any_matrix(draw):
        n = draw(st.integers(1, 40))
        m = draw(st.integers(1, 40))
        density = draw(st.sampled_from([0.0, 0.01, 0.05, 0.2]))
        if density == 0.0:
            return csr_from_coo([], [], [], (n, m))
        seed = draw(st.integers(0, 10_000))
        return random_sparse(n, m, density, seed=seed)

    @settings(max_examples=30, deadline=None)
    @given(any_matrix(), any_matrix())
    def test_prop_extract_features_invariants(A, B):
        if A.n_cols != B.n_rows:
            B = random_sparse(A.n_cols, max(B.n_cols, 1), 0.05, seed=0)
        _feat_invariants(A, B)
        z = dm.featurize(dp.extract_features(A, B))
        assert all(math.isfinite(v) for v in z)


# ---------------------------------------------------------------------------
# tools/dump_autotune.py smoke
# ---------------------------------------------------------------------------

def test_dump_autotune_cli(tmp_path, capsys):
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "tools"))
    try:
        import dump_autotune as da
    finally:
        sys.path.pop(0)
    path = str(tmp_path / "cache.json")
    c = dp.AutotuneCache(path)
    _sweep(c, sizes=(24, 48))
    c.quarantine("bad-bucket", "esc", "xla", reason="boom")
    assert da.main(["dump_autotune", "show", path]) == 0
    out = capsys.readouterr().out
    assert "schema v2" in out and "quarantined" in out
    assert da.main(["dump_autotune", "validate", path]) == 0
    export = str(tmp_path / "ds.json")
    assert da.main(["dump_autotune", "export", path,
                    "--output", export]) == 0
    ds = json.load(open(export))
    assert ds["n_samples"] == 2
    assert ds["feature_names"] == list(dm.FEATURE_NAMES)
    assert da.main(["dump_autotune", "train", path, "--steps", "40"]) == 0
    assert os.path.exists(path + dp.MODEL_SUFFIX)
    assert da.main(["dump_autotune", "compact", path,
                    "--drop-timings"]) == 0
    raw = json.load(open(path))
    assert all("timings" not in e for k, e in raw.items()
               if not k.startswith("!"))
    # validate flags a malformed file
    json.dump({"k": {"source": "autotune",
                     "timings": {"esc|": float("1e300") * 0 + 1.0}},
               "!quarantine:q": {"combos": "notalist"}},
              open(path, "w"))
    assert da.main(["dump_autotune", "validate", path]) == 1
