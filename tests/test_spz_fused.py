"""Device-resident (fused) spz driver: equality, stats, and primitives.

The fused driver must be BIT-identical to the host lock-step driver —
same engine semantics, different execution — and structure-identical to
the scl-array oracle (oracle values differ only by its float64
accumulation).  Hypothesis property tests are skipped on a bare checkout
(same guard as the rest of the suite).
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import dispatch as dp
from repro.core import spgemm_engines as sg
from repro.core import stream as kvstream
from repro.core.formats import (EMPTY, batch_csr, csr_from_coo,
                                random_sparse)
from repro.kernels import merge_tree, ref


def _dense(m):
    return np.asarray(m.to_dense(), np.float64)


def _csr_arrays(m):
    nnz = int(np.asarray(m.indptr)[-1])
    return (np.asarray(m.indptr), np.asarray(m.indices)[:nnz],
            np.asarray(m.data)[:nnz])


def _assert_drivers_identical(A, B, **kw):
    out_h, st_h = sg.spgemm_spz(A, B, driver="host", backend="xla", **kw)
    out_f, st_f = sg.spgemm_spz(A, B, driver="fused", backend="xla", **kw)
    for h, f in zip(_csr_arrays(out_h), _csr_arrays(out_f)):
        np.testing.assert_array_equal(h, f)
    assert (st_h.n_mssort, st_h.sort_elems, st_h.n_mszip, st_h.zip_elems) \
        == (st_f.n_mssort, st_f.sort_elems, st_f.n_mszip, st_f.zip_elems)
    return out_f, st_f


# ---------------------------------------------------------------------------
# fused driver vs host driver / oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pattern", ["uniform", "powerlaw", "banded"])
def test_fused_bit_identical_to_host(pattern):
    A = random_sparse(96, 96, 0.03, seed=11, pattern=pattern)
    out_f, _ = _assert_drivers_identical(A, A, R=16)
    want = _dense(sg.spgemm_scl_array(A, A))
    np.testing.assert_allclose(_dense(out_f), want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("R", [8, 16, 128])
def test_fused_chunk_widths(R):
    A = random_sparse(64, 64, 0.05, seed=5, pattern="powerlaw")
    out_f, st_f = _assert_drivers_identical(A, A, R=R)
    assert st_f.n_mssort > 0


def test_fused_rectangular_and_rsort():
    A = random_sparse(40, 70, 0.06, seed=1)
    B = random_sparse(70, 50, 0.06, seed=2)
    _assert_drivers_identical(A, B, R=16)
    Ask = random_sparse(128, 128, 0.04, seed=9, pattern="powerlaw")
    _assert_drivers_identical(Ask, Ask, R=16, S=16, rsort=True)


def test_fused_structure_identical_to_oracle():
    A = random_sparse(80, 80, 0.05, seed=3, pattern="powerlaw")
    oracle = sg.spgemm_scl_array(A, A)
    out, _ = sg.spgemm_spz(A, A, R=16, backend="xla", driver="fused")
    o_indptr, o_idx, _ = _csr_arrays(oracle)
    f_indptr, f_idx, _ = _csr_arrays(out)
    np.testing.assert_array_equal(o_indptr, f_indptr)
    np.testing.assert_array_equal(o_idx, f_idx)


def test_empty_inputs_both_drivers():
    """n_rows == 0 must not crash (np.concatenate([]) regression)."""
    E = csr_from_coo([], [], [], (0, 7))
    B = random_sparse(7, 5, 0.2, seed=0)
    for driver in ("host", "fused"):
        out, stats = sg.spgemm_spz(E, B, driver=driver)
        assert out.shape == (0, 5)
        assert int(np.asarray(out.indptr)[-1]) == 0
        assert stats.n_mssort == 0 and stats.n_mszip == 0


def test_zero_nnz_and_empty_rows():
    Z = csr_from_coo([], [], [], (8, 8))
    for driver in ("host", "fused"):
        out, _ = sg.spgemm_spz(Z, Z, driver=driver)
        assert int(np.asarray(out.indptr)[-1]) == 0
    # some empty rows, some populated
    A = csr_from_coo([1, 1, 5], [0, 3, 2], [1.0, 2.0, 3.0], (8, 8))
    _assert_drivers_identical(A, A, R=8)


def test_unknown_driver_raises():
    A = random_sparse(8, 8, 0.1, seed=0)
    with pytest.raises(ValueError, match="unknown spz driver"):
        sg.spgemm_spz(A, A, driver="nope")


# ---------------------------------------------------------------------------
# engine registry / dispatch integration
# ---------------------------------------------------------------------------

def test_registry_has_fused_engines():
    names = set(dp.available_engines())
    assert {"spz-fused", "spz-host"} <= names
    assert dp.get_engine("spz-fused").batchable
    assert not dp.get_engine("spz-host").measure


def test_dispatch_spz_fused_engine():
    A = random_sparse(48, 48, 0.04, seed=2)
    out, stats = dp.spgemm(A, A, engine="spz-fused", R=16, backend="xla",
                           return_stats=True)
    np.testing.assert_allclose(_dense(out), _dense(sg.spgemm_scl_array(A, A)),
                               rtol=1e-4, atol=1e-4)
    assert stats is not None and stats.n_mssort > 0


def test_batched_fused_matches_host_batched():
    mats = [random_sparse(32, 32, d, seed=i)
            for i, d in enumerate((0.01, 0.06, 0.02))]
    A = batch_csr(mats, batch_cap=len(mats) + 1)
    out_f = dp.spgemm_batched(A, A, engine="spz-fused", R=8, S=32)
    out_h = dp.spgemm_batched(A, A, engine="spz-host", R=8, S=32)
    for i in range(len(mats)):
        for h, f in zip(_csr_arrays(out_h[i]), _csr_arrays(out_f[i])):
            np.testing.assert_array_equal(h, f)


# ---------------------------------------------------------------------------
# device-resident primitives
# ---------------------------------------------------------------------------

def _sorted_unique_partition(rng, N, L, key_hi):
    lens = rng.integers(0, L + 1, N).astype(np.int32)
    keys = np.full((N, L), EMPTY, np.int32)
    vals = np.zeros((N, L), np.float32)
    for s in range(N):
        u = np.sort(rng.choice(key_hi, size=lens[s], replace=False))
        keys[s, :lens[s]] = u
        vals[s, :lens[s]] = rng.standard_normal(lens[s])
    return keys, vals, lens


def test_merge_partitions_equals_host_merge_round():
    """The while-loop primitive must reproduce the host merge_round
    byte-for-byte, including the mszip issue count."""
    rng = np.random.default_rng(7)
    N, L, R = 6, 32, 8
    ka, va, la = _sorted_unique_partition(rng, N, L, 3 * L)
    kb, vb, lb = _sorted_unique_partition(rng, N, L, 3 * L)
    stats = sg.SpzStats()
    hk, hv, hl = sg.merge_round((ka, va, la.astype(np.int64)),
                                 (kb, vb, lb.astype(np.int64)),
                                 R, "xla", stats)
    fk, fv, fl, cnt = kvstream.merge_partitions(ka, va, la, kb, vb, lb, R=R)
    fk, fv, fl = np.asarray(fk), np.asarray(fv), np.asarray(fl)
    np.testing.assert_array_equal(hl, fl)
    for s in range(N):
        np.testing.assert_array_equal(hk[s, :hl[s]], fk[s, :fl[s]])
        np.testing.assert_array_equal(hv[s, :hl[s]], fv[s, :fl[s]])
    assert int(cnt.n_mszip) == stats.n_mszip
    assert int(cnt.zip_elems) == stats.zip_elems


def test_merge_partitions_empty_side():
    rng = np.random.default_rng(3)
    N, L, R = 4, 16, 8
    ka, va, la = _sorted_unique_partition(rng, N, L, 2 * L)
    kb = np.full((N, L), EMPTY, np.int32)
    vb = np.zeros((N, L), np.float32)
    lb = np.zeros(N, np.int32)
    fk, fv, fl, cnt = kvstream.merge_partitions(ka, va, la, kb, vb, lb, R=R)
    np.testing.assert_array_equal(np.asarray(fl), la)
    for s in range(N):
        np.testing.assert_array_equal(np.asarray(fk)[s, :la[s]],
                                      ka[s, :la[s]])
    assert int(cnt.n_mszip) == 0 and int(cnt.zip_elems) == 0


def test_sort_chunks_linear_byte_identical_to_ref():
    rng = np.random.default_rng(0)
    for key_hi in (3, 9, 1000):  # duplicate-heavy through nearly-unique
        for _ in range(10):
            N, R = 5, 16
            lens = rng.integers(0, R + 1, N).astype(np.int32)
            keys = rng.integers(0, key_hi, (N, R)).astype(np.int32)
            vals = rng.standard_normal((N, R)).astype(np.float32)
            args = (jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lens))
            for r, f in zip(ref.stream_sort_ref(*args),
                            merge_tree.sort_chunks_linear(*args)):
                np.testing.assert_array_equal(np.asarray(r), np.asarray(f))


def test_fused_sort_merge_counters_layout():
    """Stream-level fused entry returns the 6 SpzStats counters."""
    rng = np.random.default_rng(1)
    S, L, R = 4, 32, 8
    plens = rng.integers(0, L + 1, S).astype(np.int32)
    keys = np.where(np.arange(L)[None, :] < plens[:, None],
                    rng.integers(0, 50, (S, L)), EMPTY).astype(np.int32)
    vals = np.where(np.arange(L)[None, :] < plens[:, None],
                    rng.standard_normal((S, L)), 0).astype(np.float32)
    mk, mv, ml, counters = kvstream.fused_sort_merge(
        jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(plens), R=R)
    counters = np.asarray(counters)
    assert counters.shape == (6,)
    assert counters[0] == -(-int(plens.max()) // R)  # n_mssort
    assert counters[1] == int(plens.sum())           # sort_elems
    # every stream's output is sorted unique
    mk, ml = np.asarray(mk), np.asarray(ml)
    for s in range(S):
        assert (np.diff(mk[s, :ml[s]]) > 0).all()


# ---------------------------------------------------------------------------
# dispatch feature cache
# ---------------------------------------------------------------------------

def test_feature_cache_hits_and_invalidations(monkeypatch):
    dp.clear_feature_cache()
    A = random_sparse(32, 32, 0.05, seed=4)
    calls = {"n": 0}
    real = sg.work_stats

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(sg, "work_stats", counting)
    f1 = dp.extract_features(A, A)
    f2 = dp.extract_features(A, A)
    assert calls["n"] == 1 and f1 == f2
    # a different matrix object misses
    B = random_sparse(32, 32, 0.05, seed=5)
    dp.extract_features(B, B)
    assert calls["n"] == 2
    # mutating the returned dict must not poison the cache
    f1["density"] = -1.0
    assert dp.extract_features(A, A)["density"] != -1.0
    assert calls["n"] == 2
    dp.clear_feature_cache()
    dp.extract_features(A, A)
    assert calls["n"] == 3


def test_feature_cache_bounded():
    cache = dp._OperandMemo(maxsize=4)
    for i in range(8):
        A = random_sparse(8, 8, 0.1, seed=i)
        cache.put(A, A, 16, {"i": i})
    assert len(cache._entries) == 4


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    @st.composite
    def fused_matrix(draw):
        """Random densities, skewed rows, empty rows, duplicate-heavy
        streams — the regimes the fused driver must cover."""
        n = draw(st.integers(8, 48))
        density = draw(st.floats(0.01, 0.2))
        seed = draw(st.integers(0, 10_000))
        pattern = draw(st.sampled_from(["uniform", "powerlaw", "banded",
                                        "blocked"]))
        return random_sparse(n, n, density, seed=seed, pattern=pattern)

    @settings(max_examples=20, deadline=None)
    @given(fused_matrix())
    def test_prop_fused_equals_oracle(A):
        want = _dense(sg.spgemm_scl_array(A, A))
        got = _dense(sg.spgemm_spz(A, A, R=8, backend="xla",
                                   driver="fused")[0])
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    @settings(max_examples=10, deadline=None)
    @given(fused_matrix(), st.sampled_from([8, 16]),
           st.sampled_from([16, 64]))
    def test_prop_fused_stats_match_host(A, R, S):
        """n_mszip / zip_elems (and the whole output) must match the host
        driver on the same input and lock-step parameters."""
        _assert_drivers_identical(A, A, R=R, S=S)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 8), st.integers(0, 10_000))
    def test_prop_merge_partitions_union(N, seed):
        """Merged output == sorted union with cross-side accumulation."""
        rng = np.random.default_rng(seed)
        L, R = 16, 8
        ka, va, la = _sorted_unique_partition(rng, N, L, 24)
        kb, vb, lb = _sorted_unique_partition(rng, N, L, 24)
        fk, fv, fl, _ = kvstream.merge_partitions(ka, va, la, kb, vb, lb,
                                                  R=R)
        fk, fv, fl = np.asarray(fk), np.asarray(fv), np.asarray(fl)
        for s in range(N):
            want = {}
            for k, v in list(zip(ka[s, :la[s]], va[s, :la[s]])) + \
                    list(zip(kb[s, :lb[s]], vb[s, :lb[s]])):
                want[int(k)] = want.get(int(k), np.float32(0)) + v
            keys = sorted(want)
            assert fl[s] == len(keys)
            np.testing.assert_array_equal(fk[s, :fl[s]], keys)
            np.testing.assert_allclose(fv[s, :fl[s]],
                                       [want[k] for k in keys], rtol=1e-6,
                                       atol=1e-6)
