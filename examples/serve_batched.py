"""Serving example: batched prefill+decode with the engine, greedy and
top-k sampling, the zipper top-k merge over vocab shards, and a ragged
SpGEMM request batch served through the density-aware engine registry.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import base as cb
from repro.core import dispatch, spgemm_engines as sg
from repro.core.formats import batch_csr, random_sparse
from repro.models import model as M
from repro.serving.engine import Engine, Request
from repro.serving.sampler import zipper_topk


def main():
    cfg = cb.get_smoke_config("tinyllama_1_1b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=4, max_seq=96)
    rng = np.random.default_rng(0)
    reqs = [Request(rng.integers(0, cfg.vocab_size, n, dtype=np.int32),
                    max_new_tokens=24)
            for n in (5, 9, 12, 7)]  # ragged prompts, one shared batch
    t0 = time.time()
    reqs = eng.generate(reqs)
    dt = time.time() - t0
    for i, r in enumerate(reqs):
        print(f"req{i} ({len(r.prompt)} prompt tokens) ->",
              r.out[:10].tolist(), "...")
    tok = sum(len(r.out) for r in reqs)
    print(f"{tok} tokens in {dt:.2f}s ({tok / dt:.0f} tok/s incl. compile)")

    # zipper top-k: merge per-model-shard sorted logit streams (mszip)
    shards = [rng.standard_normal(cfg.vocab_size // 4).astype(np.float32)
              for _ in range(4)]
    vals, ids = zipper_topk(shards, k=8)
    full = np.concatenate(shards)
    assert set(ids) == set(np.argsort(full)[::-1][:8])
    print("zipper top-k over 4 vocab shards matches global top-k:",
          ids.tolist())

    # SpGEMM serving path: a ragged batch of sparse multiply requests
    # (different densities, different nnz) packed into one BatchedCSR and
    # executed under a single compilation via the engine registry.
    mats = [random_sparse(128, 128, d, seed=i)
            for i, d in enumerate((0.005, 0.02, 0.01))]
    A = batch_csr(mats, batch_cap=4)  # one padded lane, ready for a 4th req
    t0 = time.time()
    out = dispatch.spgemm_batched(A, A, engine="auto")
    dt = time.time() - t0
    for i, m in enumerate(mats):
        want = np.asarray(sg.spgemm_scl_array(m, m).to_dense())
        got = np.asarray(out[i].to_dense())
        assert np.allclose(got, want, rtol=1e-4, atol=1e-4)
    print(f"spgemm_batched: {len(mats)} ragged requests (+1 padding lane) "
          f"in {dt:.2f}s incl. compile; lanes match scl-array oracle; "
          f"valid={np.asarray(out.valid).tolist()}")

    # Continuous serving: the same requests through the bucketed service
    # (plan/execute + work-balanced lane sharding). The second pass of
    # each bucket reuses the cached plan — the serving steady state.
    from repro.serving.spgemm_service import SpGemmService
    service = SpGemmService(max_batch=4, flush_timeout=0.01)
    for m in mats:                      # warmup pass plans every bucket
        service.submit(m, m)
    service.drain()
    snap = (len(service.completed), len(service.flush_log))
    for m in mats:                      # steady state: cached plans only
        service.submit(m, m)
    service.drain()
    s = service.stats(since_request=snap[0], since_flush=snap[1])
    print(f"spgemm service steady state: {s['n_requests']} reqs in "
          f"{s['n_flushes']} flushes over {s['n_buckets']} buckets; "
          f"plan_hit_rate={s['plan_hit_rate']:.2f}")


if __name__ == "__main__":
    main()
