"""End-to-end driver: train a ~100M-param MoE LM with zipper dispatch for a
few hundred steps, with checkpointing and fault-tolerant resume.

The MoE token routing uses the paper's stream-sort primitive (see
models/moe.py). ~100M params, 300 steps on CPU: expect a clearly
decreasing loss curve.

    PYTHONPATH=src python examples/train_moe_zipper.py [--steps 300]
"""
import argparse
import dataclasses

from repro.configs import base as cb
from repro.launch.train import train
from repro.optim import adamw
from repro.runtime.fault import FaultConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_ckpt")
    args = ap.parse_args()

    # ~100M params: arctic-family MoE (8 experts, top-2, dense residual)
    cfg = dataclasses.replace(
        cb.get_smoke_config("arctic_480b"),
        name="arctic-100m", d_model=256, num_heads=8, num_kv_heads=4,
        num_layers=6, d_ff=1024, moe_d_ff=1024, num_experts=8, top_k=2,
        vocab_size=32000, moe_dispatch="einsum")
    print(f"params ~= {cfg.param_count() / 1e6:.0f}M")
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, decay_steps=args.steps,
                            clip_norm=1.0)
    fcfg = FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100)
    _, hist = train(cfg, opt, fcfg, num_steps=args.steps, global_batch=8,
                    seq_len=128, log_every=20)
    losses = [h["loss"] for h in hist["steps"]]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps ({hist['saves']} checkpoints)")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
