"""Quickstart: the SparseZipper primitives and SpGEMM engine in 2 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import spgemm_engines as sg
from repro.core.formats import random_sparse
from repro.kernels import ops

# --- 1. the zipper primitives -------------------------------------------
# Four streams of key-value tuples (one per matrix-register row in the
# paper); sort each chunk, accumulating duplicate keys.
keys = jnp.asarray(np.array([[5, 2, 5, 9], [7, 7, 7, 7],
                             [3, 1, 4, 1], [0, 0, 0, 0]], np.int32))
vals = jnp.asarray(np.arange(16, dtype=np.float32).reshape(4, 4))
lens = jnp.asarray(np.array([4, 4, 4, 2], np.int32))
k, v, n = ops.stream_sort(keys, vals, lens, backend="pallas")
print("mssort  keys:", np.asarray(k))
print("        vals:", np.asarray(v))
print("        lens:", np.asarray(n), " (duplicates were accumulated)")

# Merge two sorted chunks with data-dependent advancement (mszip).
ka = jnp.asarray(np.array([[1, 3, 5, 9]], np.int32))
kb = jnp.asarray(np.array([[2, 3, 4, 100]], np.int32))
va = jnp.ones((1, 4), jnp.float32)
vb = jnp.full((1, 4), 10.0, jnp.float32)
l4 = jnp.asarray(np.array([4], np.int32))
klo, vlo, khi, vhi, ca, cb, ol = ops.stream_merge(ka, va, l4, kb, vb, l4,
                                                  backend="pallas")
print("\nmszip   merged:", np.asarray(klo)[0], "+", np.asarray(khi)[0])
print("        consumed a,b:", int(ca[0]), int(cb[0]),
      "(the 100 waits for the next chunk — merge bit unset)")

# --- 2. SpGEMM end-to-end ------------------------------------------------
A = random_sparse(256, 256, 0.02, seed=1, pattern="powerlaw")
C_ref = sg.spgemm_scl_array(A, A)          # scalar oracle
C_spz, stats = sg.spgemm_spz(A, A, R=16)   # SparseZipper merge-based
err = np.abs(np.asarray(C_ref.to_dense()) -
             np.asarray(C_spz.to_dense())).max()
print(f"\nSpGEMM 256x256 A@A: max err vs oracle = {err:.2e}")
print(f"dynamic instructions: {stats.n_mssort} mssort, {stats.n_mszip} mszip")
print(f"chunk traffic: {stats.chunk_loads} loads, {stats.chunk_stores} stores")
