"""SpGEMM application: 2-hop neighbourhoods (A@A) on synthetic graphs —
the paper's core workload — comparing all five implementations, plus the
spz-rsort work-balancing effect on a skewed (power-law) graph.

    PYTHONPATH=src python examples/spgemm_graph.py
"""
import time

import numpy as np

from repro.core import spgemm, spgemm_engines as sg
from repro.core.formats import random_sparse


def run(name, A):
    stats = sg.work_stats(A, A)
    print(f"\n=== {name}: {A.n_rows} rows, nnz={stats['nnz']}, "
          f"work/row={stats['avg_work_per_row']:.1f}, "
          f"group work var={stats['work_var_per_group']:.2f}")
    ref = None
    for method in ("scl-array", "scl-hash", "esc", "spz", "spz-rsort"):
        t0 = time.perf_counter()
        if method.startswith("spz"):
            C, st = sg.spgemm_spz(A, A, R=16, rsort=method.endswith("rsort"))
            extra = f" [{st.n_mssort} mssort + {st.n_mszip} mszip]"
        else:
            C = spgemm(A, A, engine=method)
            extra = ""
        dt = time.perf_counter() - t0
        d = np.asarray(C.to_dense())
        if ref is None:
            ref = d
        err = np.abs(d - ref).max()
        print(f"  {method:10s} {dt * 1e3:8.1f} ms  err={err:.1e}{extra}")


def main():
    run("road-like (banded, uniform work)",
        random_sparse(512, 512, 0.004, seed=0, pattern="banded"))
    run("social-like (power-law, skewed work)",
        random_sparse(512, 512, 0.008, seed=1, pattern="powerlaw"))


if __name__ == "__main__":
    main()
