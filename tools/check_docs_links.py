"""Docs-build smoke: validate markdown links in docs/ and README.md.

Checks every inline markdown link (``[text](target)``) in the doc set:

  * relative file targets must exist (anchors are stripped; a bare
    ``#anchor`` is checked against the headings of its own file);
  * ``docs/*.md`` targets of README links must themselves be in the
    checked set, so a page can't be linked but never validated;
  * http(s) links are NOT fetched (CI must not depend on the network) —
    they are only syntax-checked.

Exit code 0 when every link resolves, 1 with one line per broken link.
No third-party dependencies; runs as a blocking step of the lint lane.

Usage: python tools/check_docs_links.py [repo_root]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links; images share the syntax modulo a leading '!'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _strip_code(text: str) -> str:
    """Drop fenced code blocks and inline code spans — link syntax inside
    them is example text, not a link."""
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return re.sub(r"`[^`]*`", "", text)


def _anchors(text: str) -> set[str]:
    """GitHub-style heading anchors of one markdown document."""
    out = set()
    for title in _HEADING.findall(_strip_code(text)):
        slug = re.sub(r"[^\w\- ]", "", title.strip().lower())
        out.add(slug.replace(" ", "-"))
    return out


def check(root: Path) -> list[str]:
    docs = sorted(root.glob("docs/*.md")) + [root / "README.md"]
    errors = []
    for doc in docs:
        if not doc.exists():
            errors.append(f"{doc.relative_to(root)}: file missing")
            continue
        text = doc.read_text()
        anchors = _anchors(text)
        for target in _LINK.findall(_strip_code(text)):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            rel = doc.relative_to(root)
            if not path_part:  # same-file anchor
                if anchor and anchor not in anchors:
                    errors.append(f"{rel}: broken anchor #{anchor}")
                continue
            dest = (doc.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{rel}: broken link -> {target}")
            elif anchor and dest.suffix == ".md":
                if anchor not in _anchors(dest.read_text()):
                    errors.append(
                        f"{rel}: broken anchor -> {target}")
    return errors


def main(argv: list[str]) -> int:
    root = Path(argv[1]) if len(argv) > 1 else Path(__file__).parent.parent
    errors = check(root.resolve())
    for err in errors:
        print(f"BROKEN: {err}", file=sys.stderr)
    n_docs = len(list(root.glob('docs/*.md'))) + 1
    print(f"checked {n_docs} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken links'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
