"""Inspect, validate, compact, and export the autotune cache file.

The disk-backed ``AutotuneCache`` (``core/dispatch.py``) accumulates one
entry per shape/nnz bucket — the selected engine/backend, the source
that selected it, and (for autotune sweeps) the full per-candidate
timing vector + feature dict the learned dispatch model trains on —
plus reserved ``!quarantine:<bucket>`` records and the ``!schema``
version stamp.  This CLI is the operator's window into that file:

  show      — human summary: schema version, entries by source, timing
              coverage, active/expired quarantine combos (``--json``
              for machine output)
  validate  — structural screen of every record; exit 1 with one line
              per problem (unknown schema, missing fields, non-finite
              timings, malformed quarantine records)
  compact   — rewrite the file through the current schema: migrate
              old-format records forward, drop expired quarantine
              combos, optionally strip timing vectors (--drop-timings)
              once a model has been trained from them
  export    — the offline-training dataset (``samples_from_entries``)
              as JSON: one sample per bucket with a timing vector
  train     — fit the dispatch cost model from the cache and write the
              versioned artifact next to it (``<cache>.model.json``)

Usage: python tools/dump_autotune.py <cmd> [path] [options]
The default path is the process-default cache location.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

if "src" not in sys.path:  # repo-root invocation without PYTHONPATH=src
    sys.path.insert(
        0, os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src"))

from repro.core import dispatch as dp           # noqa: E402
from repro.models import dispatch_model as dm   # noqa: E402

_QUAR = "!quarantine:"


def _load_raw(path: str) -> dict:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    if not isinstance(data, dict):
        raise SystemExit(f"{path}: not a JSON object")
    return data


def _split(data: dict) -> tuple[int, dict, dict]:
    """(schema_version, selection entries, quarantine records)."""
    schema = data.get("!schema")
    version = int(schema["version"]) if isinstance(schema, dict) \
        and "version" in schema else 1
    sels = {k: v for k, v in data.items()
            if not k.startswith("!") and isinstance(v, dict)}
    quar = {k: v for k, v in data.items()
            if k.startswith(_QUAR) and isinstance(v, dict)}
    return version, sels, quar


def cmd_show(args) -> int:
    data = _load_raw(args.path)
    version, sels, quar = _split(data)
    by_source: dict = {}
    with_timings = 0
    n_points = 0
    for e in sels.values():
        by_source[e.get("source", "?")] = \
            by_source.get(e.get("source", "?"), 0) + 1
        if e.get("timings"):
            with_timings += 1
            n_points += len(e["timings"])
    now = time.time()
    q_rows = []
    for k, q in sorted(quar.items()):
        for combo in q.get("combos", ()):
            ts = q.get("ts", {}).get(combo)
            q_rows.append({
                "bucket": k[len(_QUAR):], "combo": combo,
                "strikes": int(q.get("strikes", {}).get(combo, 1)),
                "age_s": round(now - float(ts), 1) if ts else None,
                "reason": q.get("reasons", {}).get(combo, ""),
            })
    summary = {
        "path": args.path, "schema_version": version,
        "selection_entries": len(sels), "by_source": by_source,
        "entries_with_timings": with_timings,
        "timing_points": n_points,
        "quarantine_buckets": len(quar), "quarantined": q_rows,
    }
    if args.json:
        json.dump(summary, sys.stdout, indent=1, sort_keys=True)
        print()
        return 0
    print(f"{args.path}: schema v{version}, {len(sels)} selection "
          f"entries ({by_source or '{}'}), {with_timings} with timing "
          f"vectors ({n_points} measured points)")
    for r in q_rows:
        age = f"{r['age_s']}s ago" if r["age_s"] is not None else "unstamped"
        print(f"  quarantined {r['bucket']}: {r['combo']} "
              f"(strikes={r['strikes']}, {age}) {r['reason']}")
    if not q_rows:
        print("  no quarantined combos")
    return 0


def cmd_validate(args) -> int:
    data = _load_raw(args.path)
    version, sels, quar = _split(data)
    problems = []
    if version > dp.SCHEMA_VERSION:
        problems.append(f"!schema: version {version} is newer than this "
                        f"build's {dp.SCHEMA_VERSION}")
    for k, v in data.items():
        if not isinstance(v, dict):
            problems.append(f"{k}: entry is not an object")
    for k, e in sels.items():
        if not e.get("engine") or not e.get("source"):
            problems.append(f"{k}: missing engine/source")
        for combo, t in (e.get("timings") or {}).items():
            if not isinstance(t, (int, float)) or not math.isfinite(t) \
                    or t <= 0:
                problems.append(f"{k}: timing {combo}={t!r} not a "
                                "positive finite number")
        for name, val in (e.get("features") or {}).items():
            if not isinstance(val, (int, float)) \
                    or not math.isfinite(float(val)):
                problems.append(f"{k}: feature {name}={val!r} not finite")
    for k, q in quar.items():
        combos = q.get("combos")
        if not isinstance(combos, list):
            problems.append(f"{k}: quarantine combos is not a list")
            continue
        for combo in combos:
            if "|" not in str(combo):
                problems.append(f"{k}: malformed combo {combo!r}")
            ts = q.get("ts", {}).get(combo)
            if ts is not None and (not isinstance(ts, (int, float))
                                   or not math.isfinite(ts)):
                problems.append(f"{k}: bad timestamp for {combo!r}: {ts!r}")
    for p in problems:
        print(f"INVALID: {p}", file=sys.stderr)
    print(f"{args.path}: {len(sels)} entries, {len(quar)} quarantine "
          f"records: {'OK' if not problems else f'{len(problems)} problems'}")
    return 1 if problems else 0


def cmd_compact(args) -> int:
    cache = dp.AutotuneCache(args.path)
    before = os.path.getsize(args.path) if os.path.exists(args.path) else 0
    entries = cache.entries()
    dropped_combos = 0
    for k in list(entries):
        if k.startswith(_QUAR):
            # quarantined() re-admits expired combos in memory as a side
            # effect; the flush below persists the pruned record
            bucket = k[len(_QUAR):]
            active = cache.quarantined(bucket)
            dropped_combos += len(entries[k].get("combos", ())) - len(active)
    if args.drop_timings:
        with cache._mu:  # noqa: SLF001 - maintenance tool, exact rewrite
            for k, e in cache._load().items():  # noqa: SLF001
                if not k.startswith("!"):
                    e.pop("timings", None)
                    e.pop("features", None)
    # merge=False: the default flush re-unions on-disk dataset fields,
    # which would resurrect the timing vectors we just stripped
    cache._flush(merge=False)  # noqa: SLF001
    after = os.path.getsize(args.path) if os.path.exists(args.path) else 0
    print(f"{args.path}: compacted {before} -> {after} bytes "
          f"(schema v{dp.SCHEMA_VERSION}, {dropped_combos} expired "
          f"quarantine combos dropped"
          f"{', timing vectors stripped' if args.drop_timings else ''})")
    return 0


def cmd_export(args) -> int:
    cache = dp.AutotuneCache(args.path)
    samples = dm.samples_from_entries(cache.entries())
    out = {"source": args.path, "n_samples": len(samples),
           "feature_names": list(dm.FEATURE_NAMES), "samples": samples}
    if args.output:
        with open(args.output, "w") as f:
            json.dump(out, f, indent=1)
        print(f"wrote {len(samples)} samples -> {args.output}")
    else:
        json.dump(out, sys.stdout, indent=1)
        print()
    return 0


def cmd_train(args) -> int:
    cache = dp.AutotuneCache(args.path)
    artifact = args.artifact or dp.model_path_for(cache)
    model = dm.train_and_save(cache.entries(), artifact, steps=args.steps)
    if model is None:
        print(f"{args.path}: no timing vectors to train from "
              "(run autotune sweeps first)", file=sys.stderr)
        return 1
    print(f"trained v{model.version} on {model.n_samples} buckets "
          f"({len(model.candidates)} candidates, sigma={model.sigma:.3f}) "
          f"-> {artifact}")
    return 0


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    # same resolution as the dispatch layer ($REPRO_AUTOTUNE_CACHE or
    # the ~/.cache/repro default)
    default_path = dp.AutotuneCache().path

    def add(name, fn, **extra):
        p = sub.add_parser(name)
        p.add_argument("path", nargs="?", default=default_path,
                       help=f"cache file (default {default_path})")
        p.set_defaults(fn=fn)
        for flag, kw in extra.items():
            p.add_argument(flag, **kw)
        return p

    add("show", cmd_show, **{"--json": {"action": "store_true"}})
    add("validate", cmd_validate)
    add("compact", cmd_compact,
        **{"--drop-timings": {"action": "store_true",
                              "help": "strip timing vectors + features "
                                      "(keeps the winners)"}})
    add("export", cmd_export,
        **{"--output": {"default": None, "help": "write here, not stdout"}})
    add("train", cmd_train,
        **{"--artifact": {"default": None,
                          "help": "artifact path (default: next to cache)"},
           "--steps": {"type": int, "default": 400}})
    args = ap.parse_args(argv[1:])
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
